//! Non-coherent cache controllers: the RDMA-WB-NC, SM-WB-NC and SM-WT-NC
//! baselines (paper §4.1).
//!
//! No timestamps, no invalidations: coherence is the *programmer's*
//! responsibility, which the paper's standard benchmarks discharge at
//! kernel boundaries. The driver models that contract with fences:
//! a fence drops every (clean) line and, under write-back, first drains
//! dirty lines to MM — the hardware equivalent of the manual
//! flush/invalidate a GPU programmer performs between kernels.
//!
//! The write-back L2 reproduces the paper's §5.1 bottleneck: a miss whose
//! victim is dirty must complete the write-back *before* the fill is
//! issued, serializing evictions behind the L2<->MM network.

use crate::coherence::{L1Routes, L2Routes, WritePolicy};
use crate::mem::cache::{CacheArray, CacheParams};
use crate::mem::fxhash::{FxHashMap, FxHashSet};
use crate::mem::mshr::{Mshr, MshrKind};
use crate::mem::LineBuf;
use crate::metrics::CacheCtrlStats;
use crate::sim::msg::{MemReq, MemRsp};
use crate::sim::{CompId, Component, Ctx, Cycle, Msg, ReqKind};

/// Reserved id space for controller-generated write-backs.
const WB_ID_BASE: u64 = 1 << 62;

/// Plain write-through, no-write-allocate L1 (all NC configs + HMG).
pub struct PlainL1 {
    name: String,
    routes: L1Routes,
    cache: CacheArray<()>,
    mshr: Mshr,
    lat: Cycle,
    /// Write-combining buffer (same semantics as HalconeL1's).
    coalesce: FxHashMap<u64, Vec<(u64, LineBuf)>>,
    /// Coalesced requests awaiting their flush's completion.
    pending_acks: FxHashMap<u64, Vec<MemReq>>,
    pub stats: CacheCtrlStats,
    /// Per-tenant mirror of the CU-request hit/miss bumps (mix runs;
    /// single-tenant traffic lands in slot 0).
    pub tstats: crate::metrics::tenancy::TenantTraffic,
    line: u64,
}

impl PlainL1 {
    pub fn new(
        name: impl Into<String>,
        routes: L1Routes,
        params: CacheParams,
        mshr_entries: usize,
        lat: Cycle,
    ) -> Self {
        let line = params.line;
        PlainL1 {
            name: name.into(),
            routes,
            cache: CacheArray::new(params),
            mshr: Mshr::new(mshr_entries),
            lat,
            coalesce: FxHashMap::default(),
            pending_acks: FxHashMap::default(),
            stats: CacheCtrlStats::default(),
            tstats: crate::metrics::tenancy::TenantTraffic::default(),
            line,
        }
    }

    fn line_base(&self, addr: u64) -> u64 {
        addr & !(self.line - 1)
    }

    fn respond_word(&mut self, req: &MemReq, line_data: &[u8], ctx: &mut Ctx) {
        let off = (req.addr - self.line_base(req.addr)) as usize;
        let data = LineBuf::from_slice(&line_data[off..off + req.size as usize]);
        self.respond_sliced(req, data, ctx);
    }

    /// Respond with already-sliced payload bytes.
    fn respond_sliced(&mut self, req: &MemReq, data: LineBuf, ctx: &mut Ctx) {
        let rsp = MemRsp {
            id: req.id,
            kind: ReqKind::Read,
            addr: req.addr,
            dst: req.src,
            data,
            ts: None,
        };
        self.stats.rsps_out += 1;
        let msg = ctx.rsp_msg(rsp);
        ctx.schedule(self.lat, req.src, msg);
    }

    fn respond_ack(&mut self, req: &MemReq, ctx: &mut Ctx) {
        let rsp = MemRsp {
            id: req.id,
            kind: ReqKind::Write,
            addr: req.addr,
            dst: req.src,
            data: LineBuf::empty(),
            ts: None,
        };
        self.stats.rsps_out += 1;
        let msg = ctx.rsp_msg(rsp);
        ctx.schedule(self.lat, req.src, msg);
    }

    fn send_down(&mut self, down: MemReq, ctx: &mut Ctx) {
        let (link, next, _) = self.routes.route(down.addr);
        self.stats.reqs_down += 1;
        self.stats.bytes_down += down.wire_bytes();
        let bytes = down.wire_bytes();
        let msg = ctx.req_msg(down);
        ctx.send(link, next, bytes, msg);
    }

    fn on_cu_req(&mut self, now: Cycle, req: MemReq, ctx: &mut Ctx) {
        let la = self.line_base(req.addr);
        if let Some(entry) = self.mshr.get(la) {
            // Coalesce writes behind a pending write (see HalconeL1).
            if entry.kind == MshrKind::WriteLock && req.kind == ReqKind::Write {
                if let Some(line) = self.cache.lookup(req.addr) {
                    let off = (req.addr - la) as usize;
                    line.data[off..off + req.data.len()].copy_from_slice(&req.data);
                }
                self.coalesce.entry(la).or_default().push((req.addr, req.data));
                self.pending_acks.entry(la).or_default().push(req);
                return;
            }
            self.stats.mshr_merges += 1;
            self.mshr.merge(la, req);
            return;
        }
        match req.kind {
            ReqKind::Read => {
                let off = (req.addr - la) as usize;
                let mut hit_data = None;
                if let Some(line) = self.cache.lookup(req.addr) {
                    hit_data = Some(LineBuf::from_slice(
                        &line.data[off..off + req.size as usize],
                    ));
                }
                if let Some(data) = hit_data {
                    self.cache.record(true);
                    self.stats.hits += 1;
                    self.tstats.slot(req.tenant).hits += 1;
                    self.respond_sliced(&req, data, ctx);
                    return;
                }
                self.cache.record(false);
                self.stats.misses += 1;
                self.tstats.slot(req.tenant).misses += 1;
                let fill = MemReq {
                    id: req.id,
                    kind: ReqKind::Read,
                    addr: la,
                    size: self.line as u32,
                    src: ctx.self_id,
                    dst: self.routes.route(la).2,
                    data: LineBuf::empty(),
                    warpts: None,
                    tenant: req.tenant,
                };
                self.mshr.allocate(la, MshrKind::Fill, req);
                self.send_down(fill, ctx);
            }
            ReqKind::Write => {
                // WT + no-write-allocate: update resident copy, forward.
                let mut hit = false;
                if let Some(line) = self.cache.lookup(req.addr) {
                    hit = true;
                    let off = (req.addr - la) as usize;
                    line.data[off..off + req.data.len()].copy_from_slice(&req.data);
                }
                self.cache.record(hit);
                if hit {
                    self.stats.hits += 1;
                    self.tstats.slot(req.tenant).hits += 1;
                } else {
                    self.stats.misses += 1;
                    self.tstats.slot(req.tenant).misses += 1;
                }
                let down = MemReq {
                    id: req.id,
                    kind: ReqKind::Write,
                    addr: req.addr,
                    size: req.size,
                    src: ctx.self_id,
                    dst: self.routes.route(req.addr).2,
                    data: req.data,
                    warpts: None,
                    tenant: req.tenant,
                };
                self.mshr.allocate(la, MshrKind::WriteLock, req);
                self.send_down(down, ctx);
            }
        }
        let _ = now;
    }

    /// Diagnostic snapshot (tests/debugging).
    pub fn debug_state(&self) -> String {
        format!(
            "mshr={} coalesce={} pending_acks={}",
            self.mshr.len(),
            self.coalesce.len(),
            self.pending_acks.values().map(|v| v.len()).sum::<usize>()
        )
    }

    fn on_down_rsp(&mut self, now: Cycle, rsp: MemRsp, ctx: &mut Ctx) {
        self.stats.rsps_down += 1;
        let la = self.line_base(rsp.addr);
        let entry = self.mshr.retire(la);
        match entry.kind {
            MshrKind::Fill => {
                debug_assert_eq!(rsp.data.len() as u64, self.line);
                self.cache.insert(la, &rsp.data, false, ());
                self.respond_word(&entry.primary, &rsp.data, ctx);
            }
            MshrKind::WriteLock => {
                let primary = entry.primary;
                if primary.src != CompId::NONE {
                    self.respond_ack(&primary, ctx);
                }
                if let Some(buf) = self.coalesce.remove(&la) {
                    let mut runs = crate::coherence::halcone::coalesce_runs(buf);
                    let (addr, data) = runs.remove(0);
                    if !runs.is_empty() {
                        self.coalesce.insert(la, runs);
                    }
                    let down = MemReq {
                        id: crate::coherence::FLUSH_REQ_ID,
                        kind: ReqKind::Write,
                        addr,
                        size: data.len() as u32,
                        src: ctx.self_id,
                        dst: self.routes.route(addr).2,
                        data,
                        warpts: None,
                        tenant: primary.tenant,
                    };
                    let synthetic = MemReq { src: CompId::NONE, ..down };
                    self.mshr.allocate(la, MshrKind::WriteLock, synthetic);
                    for w in entry.waiters {
                        self.mshr.merge(la, w);
                    }
                    self.send_down(down, ctx);
                    return;
                }
                if let Some(acks) = self.pending_acks.remove(&la) {
                    for r in acks {
                        self.respond_ack(&r, ctx);
                    }
                }
            }
        }
        for w in entry.waiters {
            self.on_cu_req(now, w, ctx);
        }
    }
}

impl Component for PlainL1 {
    crate::impl_component_any!();

    fn name(&self) -> &str {
        &self.name
    }

    fn handle(&mut self, now: Cycle, msg: Msg, ctx: &mut Ctx) {
        match msg {
            Msg::Req(req) => {
                self.stats.reqs_in += 1;
                let req = ctx.reclaim_req(req);
                self.on_cu_req(now, req, ctx);
            }
            Msg::Rsp(rsp) => {
                let rsp = ctx.reclaim_rsp(rsp);
                self.on_down_rsp(now, rsp, ctx);
            }
            Msg::FenceQuery { reply_to } => {
                ctx.schedule(0, reply_to, Msg::FenceInfo { from: ctx.self_id, cts: 0 });
            }
            Msg::FenceApply { reply_to, .. } => {
                debug_assert!(self.mshr.is_empty(), "fence with in-flight requests");
                // WT: all lines clean; the programmer-maintained coherence
                // contract is "invalidate everything at the boundary".
                self.cache.clear();
                ctx.schedule(0, reply_to, Msg::FenceDone { from: ctx.self_id });
            }
            Msg::Inv { addr, dir, .. } => {
                // HMG software-coherent L1: honour invalidations if they
                // ever reach L1 (not used by default, kept for symmetry).
                self.cache.invalidate(addr);
                self.stats.invalidations += 1;
                ctx.schedule(0, dir, Msg::InvAck { addr, from: ctx.self_id, dst: dir });
            }
            other => panic!("{}: unexpected {:?}", self.name, other),
        }
    }

    fn save_state(&self, out: &mut Vec<u8>) -> Result<(), String> {
        use crate::snapshot::format as f;
        self.cache.save_with(out, |_, _| {});
        self.mshr.save_state(out);
        let mut keys: Vec<u64> = self.coalesce.keys().copied().collect();
        keys.sort_unstable();
        f::put(out, keys.len() as u64);
        for la in keys {
            f::put(out, la);
            let buf = &self.coalesce[&la];
            f::put(out, buf.len() as u64);
            for (addr, bytes) in buf {
                f::put(out, *addr);
                f::put_buf(out, bytes);
            }
        }
        let mut keys: Vec<u64> = self.pending_acks.keys().copied().collect();
        keys.sort_unstable();
        f::put(out, keys.len() as u64);
        for la in keys {
            f::put(out, la);
            let acks = &self.pending_acks[&la];
            f::put(out, acks.len() as u64);
            for r in acks {
                f::put_req(out, r);
            }
        }
        self.stats.save_state(out);
        self.tstats.save_state(out);
        Ok(())
    }

    fn load_state(&mut self, cur: &mut crate::snapshot::format::Cur) -> Result<(), String> {
        use crate::snapshot::format as f;
        self.cache.load_with(cur, |_| Ok(()))?;
        self.mshr.load_state(cur)?;
        let n = cur.u64("l1 coalesce count")? as usize;
        self.coalesce.clear();
        for _ in 0..n {
            let la = cur.u64("l1 coalesce line")?;
            let m = cur.u64("l1 coalesce run count")? as usize;
            if m > cur.b.len() {
                return Err(format!("coalesce run count {m} exceeds the input size"));
            }
            let mut buf = Vec::with_capacity(m);
            for _ in 0..m {
                let addr = cur.u64("l1 coalesce addr")?;
                buf.push((addr, f::read_buf(cur, "l1 coalesce bytes")?));
            }
            if self.coalesce.insert(la, buf).is_some() {
                return Err(format!("snapshot repeats coalesce line {la:#x}"));
            }
        }
        let n = cur.u64("l1 pending-ack count")? as usize;
        self.pending_acks.clear();
        for _ in 0..n {
            let la = cur.u64("l1 pending-ack line")?;
            let m = cur.u64("l1 pending-ack req count")? as usize;
            if m > cur.b.len() {
                return Err(format!("pending-ack req count {m} exceeds the input size"));
            }
            let mut acks = Vec::with_capacity(m);
            for _ in 0..m {
                acks.push(f::read_req(cur, "l1 pending ack")?);
            }
            if self.pending_acks.insert(la, acks).is_some() {
                return Err(format!("snapshot repeats pending-ack line {la:#x}"));
            }
        }
        self.stats.load_state(cur)?;
        self.tstats.load_state(cur)?;
        Ok(())
    }
}

/// A fill stalled behind its victim's write-back.
#[derive(Debug)]
struct StalledFill {
    line_addr: u64,
}

/// Plain L2 bank with configurable WT/WB policy.
pub struct PlainL2 {
    name: String,
    routes: L2Routes,
    policy: WritePolicy,
    cache: CacheArray<()>,
    mshr: Mshr,
    lat: Cycle,
    /// WB: write-back id -> the fill waiting on it.
    evict_wait: FxHashMap<u64, StalledFill>,
    /// WB ids whose acks carry no further action (insert-time evictions).
    fire_and_forget: FxHashSet<u64>,
    next_wb_id: u64,
    /// Outstanding fence write-backs + who to tell when drained.
    fence_pending: u64,
    fence_reply: Option<CompId>,
    pub stats: CacheCtrlStats,
    line: u64,
}

impl PlainL2 {
    pub fn new(
        name: impl Into<String>,
        routes: L2Routes,
        policy: WritePolicy,
        params: CacheParams,
        mshr_entries: usize,
        lat: Cycle,
    ) -> Self {
        let line = params.line;
        PlainL2 {
            name: name.into(),
            routes,
            policy,
            cache: CacheArray::new(params),
            mshr: Mshr::new(mshr_entries),
            lat,
            evict_wait: FxHashMap::default(),
            fire_and_forget: FxHashSet::default(),
            next_wb_id: WB_ID_BASE,
            fence_pending: 0,
            fence_reply: None,
            stats: CacheCtrlStats::default(),
            line,
        }
    }

    fn line_base(&self, addr: u64) -> u64 {
        addr & !(self.line - 1)
    }

    fn respond_up(&mut self, req: &MemReq, data: LineBuf, ctx: &mut Ctx) {
        let rsp = MemRsp {
            id: req.id,
            kind: req.kind,
            addr: req.addr,
            dst: req.src,
            data,
            ts: None,
        };
        self.stats.rsps_out += 1;
        self.stats.bytes_up += rsp.wire_bytes();
        let (link, next) = self.routes.route_up(req.src);
        let bytes = rsp.wire_bytes();
        let msg = ctx.rsp_msg(rsp);
        ctx.send_delayed(self.lat, link, next, bytes, msg);
    }

    fn send_mm(&mut self, down: MemReq, ctx: &mut Ctx) {
        let (link, next, _) = self.routes.route_mm(down.addr);
        self.stats.reqs_down += 1;
        self.stats.bytes_down += down.wire_bytes();
        let bytes = down.wire_bytes();
        let msg = ctx.req_msg(down);
        ctx.send(link, next, bytes, msg);
    }

    fn writeback(&mut self, addr: u64, data: LineBuf, ctx: &mut Ctx) -> u64 {
        let id = self.next_wb_id;
        self.next_wb_id += 1;
        self.stats.writebacks += 1;
        let wb = MemReq {
            id,
            kind: ReqKind::Write,
            addr,
            size: data.len() as u32,
            src: ctx.self_id,
            dst: self.routes.route_mm(addr).2,
            data,
            warpts: None,
            tenant: 0,
        };
        self.send_mm(wb, ctx);
        id
    }

    fn send_fill(&mut self, la: u64, id: u64, ctx: &mut Ctx) {
        let fill = MemReq {
            id,
            kind: ReqKind::Read,
            addr: la,
            size: self.line as u32,
            src: ctx.self_id,
            dst: self.routes.route_mm(la).2,
            data: LineBuf::empty(),
            warpts: None,
            tenant: 0,
        };
        self.send_mm(fill, ctx);
    }

    /// WB insert helper: insert-time dirty evictions become fire-and-forget
    /// write-backs (the pre-fill drain handles the common case; this covers
    /// set races between concurrent fills).
    fn insert_wb_safe(&mut self, la: u64, data: &[u8], dirty: bool, ctx: &mut Ctx) {
        if let Some(ev) = self.cache.insert(la, data, dirty, ()) {
            if ev.dirty {
                let id = self.writeback(ev.addr, ev.data, ctx);
                self.fire_and_forget.insert(id);
            }
        }
    }

    /// Begin a miss: under WB, drain a dirty victim first (paper §5.1).
    /// `take_dirty_victim` removes and returns the victim in one set scan
    /// (clean victims stay resident until the fill's insert, exactly as
    /// the old `would_evict` + `invalidate` pair behaved).
    fn start_fill(&mut self, la: u64, id: u64, ctx: &mut Ctx) {
        if self.policy == WritePolicy::WriteBack {
            if let Some(ev) = self.cache.take_dirty_victim(la) {
                let wb_id = self.writeback(ev.addr, ev.data, ctx);
                self.evict_wait.insert(wb_id, StalledFill { line_addr: la });
                return;
            }
        }
        self.send_fill(la, id, ctx);
    }

    fn on_up_req(&mut self, now: Cycle, req: MemReq, ctx: &mut Ctx) {
        let la = self.line_base(req.addr);
        if self.mshr.get(la).is_some() {
            self.stats.mshr_merges += 1;
            self.mshr.merge(la, req);
            return;
        }
        match req.kind {
            ReqKind::Read => {
                let mut hit_data = None;
                if let Some(line) = self.cache.lookup(req.addr) {
                    hit_data = Some(LineBuf::from_slice(line.data));
                }
                if let Some(data) = hit_data {
                    self.cache.record(true);
                    self.stats.hits += 1;
                    self.respond_up(&req, data, ctx);
                    return;
                }
                self.cache.record(false);
                self.stats.misses += 1;
                let id = req.id;
                self.mshr.allocate(la, MshrKind::Fill, req);
                self.start_fill(la, id, ctx);
            }
            ReqKind::Write => match self.policy {
                WritePolicy::WriteThrough => {
                    let mut hit = false;
                    if let Some(line) = self.cache.lookup(req.addr) {
                        hit = true;
                        let off = (req.addr - la) as usize;
                        line.data[off..off + req.data.len()].copy_from_slice(&req.data);
                    }
                    self.cache.record(hit);
                    if hit {
                        self.stats.hits += 1;
                    } else {
                        self.stats.misses += 1;
                    }
                    let down = MemReq {
                        id: req.id,
                        kind: ReqKind::Write,
                        addr: req.addr,
                        size: req.size,
                        src: ctx.self_id,
                        dst: self.routes.route_mm(req.addr).2,
                        data: req.data,
                        warpts: None,
                        tenant: req.tenant,
                    };
                    self.mshr.allocate(la, MshrKind::WriteLock, req);
                    self.send_mm(down, ctx);
                }
                WritePolicy::WriteBack => {
                    let mut hit = false;
                    if let Some(line) = self.cache.lookup(req.addr) {
                        hit = true;
                        *line.dirty = true;
                        let off = (req.addr - la) as usize;
                        line.data[off..off + req.data.len()].copy_from_slice(&req.data);
                    }
                    self.cache.record(hit);
                    if hit {
                        // Write hit absorbs in the L2: no MM traffic at all.
                        self.stats.hits += 1;
                        self.respond_up(&req, LineBuf::empty(), ctx);
                        return;
                    }
                    self.stats.misses += 1;
                    // Write-allocate: fetch the line, then merge the word.
                    let id = req.id;
                    self.mshr.allocate(la, MshrKind::Fill, req);
                    self.start_fill(la, id, ctx);
                }
            },
        }
        let _ = now;
    }

    fn on_mm_rsp(&mut self, now: Cycle, rsp: MemRsp, ctx: &mut Ctx) {
        // Controller-generated ids first.
        if self.fire_and_forget.remove(&rsp.id) {
            return;
        }
        if let Some(stalled) = self.evict_wait.remove(&rsp.id) {
            // Victim drained: issue the deferred fill.
            let id = self
                .mshr
                .get(stalled.line_addr)
                .expect("stalled fill lost its MSHR entry")
                .primary
                .id;
            self.send_fill(stalled.line_addr, id, ctx);
            return;
        }
        if rsp.id >= WB_ID_BASE {
            // Fence write-back ack.
            if self.fence_pending > 0 {
                self.fence_pending -= 1;
                if self.fence_pending == 0 {
                    if let Some(reply) = self.fence_reply.take() {
                        ctx.schedule(0, reply, Msg::FenceDone { from: ctx.self_id });
                    }
                }
            }
            return;
        }

        self.stats.rsps_down += 1;
        let la = self.line_base(rsp.addr);
        let entry = self.mshr.retire(la);
        match entry.kind {
            MshrKind::Fill => {
                debug_assert_eq!(rsp.data.len() as u64, self.line);
                let mut data = rsp.data;
                let primary = entry.primary;
                match primary.kind {
                    ReqKind::Read => {
                        self.insert_wb_safe(la, &data, false, ctx);
                        self.respond_up(&primary, data, ctx);
                    }
                    ReqKind::Write => {
                        // WB write-allocate: merge the word, mark dirty.
                        let off = (primary.addr - la) as usize;
                        data[off..off + primary.data.len()].copy_from_slice(&primary.data);
                        self.insert_wb_safe(la, &data, true, ctx);
                        self.respond_up(&primary, LineBuf::empty(), ctx);
                    }
                }
            }
            MshrKind::WriteLock => {
                // WT write completed at MM. Allocate the merged line
                // (mirrors the HALCONE L2's write-allocate for a fair
                // WT-vs-WT comparison).
                if self.cache.peek(la).is_none() {
                    debug_assert_eq!(rsp.data.len() as u64, self.line);
                    self.insert_wb_safe(la, &rsp.data, false, ctx);
                }
                self.respond_up(&entry.primary, LineBuf::empty(), ctx);
            }
        }
        for w in entry.waiters {
            self.on_up_req(now, w, ctx);
        }
    }

    fn on_fence(&mut self, reply_to: CompId, ctx: &mut Ctx) {
        debug_assert!(self.mshr.is_empty(), "fence with in-flight requests");
        let drained = self.cache.drain();
        let mut pending = 0;
        for ev in drained {
            if ev.dirty {
                self.writeback(ev.addr, ev.data, ctx);
                pending += 1;
            }
        }
        if pending == 0 {
            ctx.schedule(0, reply_to, Msg::FenceDone { from: ctx.self_id });
        } else {
            self.fence_pending = pending;
            self.fence_reply = Some(reply_to);
        }
    }
}

impl Component for PlainL2 {
    crate::impl_component_any!();

    fn name(&self) -> &str {
        &self.name
    }

    fn handle(&mut self, now: Cycle, msg: Msg, ctx: &mut Ctx) {
        match msg {
            Msg::Req(req) => {
                self.stats.reqs_in += 1;
                let req = ctx.reclaim_req(req);
                self.on_up_req(now, req, ctx);
            }
            Msg::Rsp(rsp) => {
                let rsp = ctx.reclaim_rsp(rsp);
                self.on_mm_rsp(now, rsp, ctx);
            }
            Msg::FenceQuery { reply_to } => {
                ctx.schedule(0, reply_to, Msg::FenceInfo { from: ctx.self_id, cts: 0 });
            }
            Msg::FenceApply { reply_to, .. } => self.on_fence(reply_to, ctx),
            other => panic!("{}: unexpected {:?}", self.name, other),
        }
    }

    fn save_state(&self, out: &mut Vec<u8>) -> Result<(), String> {
        use crate::snapshot::format as f;
        self.cache.save_with(out, |_, _| {});
        self.mshr.save_state(out);
        let mut ids: Vec<u64> = self.evict_wait.keys().copied().collect();
        ids.sort_unstable();
        f::put(out, ids.len() as u64);
        for id in ids {
            f::put(out, id);
            f::put(out, self.evict_wait[&id].line_addr);
        }
        let mut ids: Vec<u64> = self.fire_and_forget.iter().copied().collect();
        ids.sort_unstable();
        f::put(out, ids.len() as u64);
        for id in ids {
            f::put(out, id);
        }
        f::put(out, self.next_wb_id);
        f::put(out, self.fence_pending);
        f::put_bool(out, self.fence_reply.is_some());
        if let Some(reply) = self.fence_reply {
            f::put(out, reply.0 as u64);
        }
        self.stats.save_state(out);
        Ok(())
    }

    fn load_state(&mut self, cur: &mut crate::snapshot::format::Cur) -> Result<(), String> {
        self.cache.load_with(cur, |_| Ok(()))?;
        self.mshr.load_state(cur)?;
        let n = cur.u64("l2 evict-wait count")? as usize;
        self.evict_wait.clear();
        for _ in 0..n {
            let id = cur.u64("l2 evict-wait id")?;
            let line_addr = cur.u64("l2 evict-wait line")?;
            if self.evict_wait.insert(id, StalledFill { line_addr }).is_some() {
                return Err(format!("snapshot repeats evict-wait id {id}"));
            }
        }
        let n = cur.u64("l2 fire-and-forget count")? as usize;
        self.fire_and_forget.clear();
        for _ in 0..n {
            let id = cur.u64("l2 fire-and-forget id")?;
            if !self.fire_and_forget.insert(id) {
                return Err(format!("snapshot repeats fire-and-forget id {id}"));
            }
        }
        self.next_wb_id = cur.u64("l2 next_wb_id")?;
        self.fence_pending = cur.u64("l2 fence_pending")?;
        self.fence_reply = if cur.bool("l2 fence_reply flag")? {
            Some(CompId(cur.u32("l2 fence_reply")?))
        } else {
            None
        };
        self.stats.load_state(cur)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dram::{GlobalMemory, MemCtrl, SharedMemory};
    use crate::interconnect::Switch;
    use crate::mem::addr::Topology;
    use crate::mem::AddrMap;
    use crate::sim::{Engine, Link};
    use std::collections::HashMap as Map;

    struct Prober {
        name: String,
        l1: CompId,
        script: Vec<(Cycle, MemReq)>,
        pub responses: Vec<(Cycle, MemRsp)>,
    }
    impl Component for Prober {
        crate::impl_component_any!();
        fn name(&self) -> &str {
            &self.name
        }
        fn handle(&mut self, now: Cycle, msg: Msg, ctx: &mut Ctx) {
            match msg {
                Msg::Tick => {
                    for (t, req) in std::mem::take(&mut self.script) {
                        let mut r = req;
                        r.src = ctx.self_id;
                        ctx.schedule(t - now, self.l1, Msg::Req(Box::new(r)));
                    }
                }
                Msg::Rsp(rsp) => self.responses.push((now, *rsp)),
                _ => {}
            }
        }
    }

    struct Rig {
        engine: Engine,
        mem: SharedMemory,
        prober: CompId,
        l1: CompId,
        l2: CompId,
    }

    fn rd(id: u64, addr: u64) -> MemReq {
        MemReq {
            id,
            kind: ReqKind::Read,
            addr,
            size: 4,
            src: CompId::NONE,
            dst: CompId::NONE,
            data: LineBuf::empty(),
            warpts: None,
            tenant: 0,
        }
    }

    fn wr(id: u64, addr: u64, v: f32) -> MemReq {
        MemReq {
            id,
            kind: ReqKind::Write,
            addr,
            size: 4,
            src: CompId::NONE,
            dst: CompId::NONE,
            data: LineBuf::from_slice(&v.to_le_bytes()),
            warpts: None,
            tenant: 0,
        }
    }

    fn build(policy: WritePolicy, l2_bytes: u64, script: Vec<(Cycle, MemReq)>) -> Rig {
        let mut e = Engine::new();
        let mem = GlobalMemory::new_shared();
        let map = AddrMap::new(Topology::SharedMem, 1, 1, 1, 1 << 20);
        let prober = CompId(0);
        let l1 = CompId(1);
        let l2 = CompId(2);
        let sw = CompId(3);
        let mc = CompId(4);
        let l1_l2 = e.add_link(Link::wire("l1->l2", 5));
        let l2_l1 = e.add_link(Link::wire("l2->l1", 5));
        let l2_sw = e.add_link(Link::new("l2->sw", 20, 256));
        let sw_l2 = e.add_link(Link::new("sw->l2", 20, 256));
        let mc_sw = e.add_link(Link::new("mc->sw", 20, 341));
        let sw_mc = e.add_link(Link::new("sw->mc", 20, 341));
        let mut swc = Switch::new("sw");
        swc.add_route(l2, (sw_l2, l2));
        swc.add_route(mc, (sw_mc, mc));

        e.add(Box::new(Prober { name: "cu".into(), l1, script, responses: vec![] }));
        e.add(Box::new(PlainL1::new(
            "l1",
            L1Routes {
                map: map.clone(),
                gpu: 0,
                local_links: vec![l1_l2],
                local_banks: vec![l2],
                remote_hop: None,
                all_banks: vec![],
            },
            CacheParams::new(16 << 10, 4),
            64,
            1,
        )));
        let mut up = Map::new();
        up.insert(l1, l2_l1);
        e.add(Box::new(PlainL2::new(
            "l2",
            L2Routes {
                map: map.clone(),
                gpu: 0,
                mm_hop: (l2_sw, sw),
                mcs: vec![mc],
                up_routes: up,
                up_default: None,
                peer_hop: None,
                all_banks: vec![],
            },
            policy,
            CacheParams::new(l2_bytes, 16),
            256,
            10,
        )));
        e.add(Box::new(swc));
        e.add(Box::new(MemCtrl::new("mm0", mem.clone(), (mc_sw, sw), 100, None)));
        e.post(0, prober, Msg::Tick);
        Rig { engine: e, mem, prober, l1, l2 }
    }

    fn f32_of(rsp: &MemRsp) -> f32 {
        f32::from_le_bytes([rsp.data[0], rsp.data[1], rsp.data[2], rsp.data[3]])
    }

    #[test]
    fn wt_write_reaches_memory() {
        let mut rig = build(WritePolicy::WriteThrough, 256 << 10, vec![(0, wr(1, 0x100, 3.0))]);
        rig.engine.run_to_completion();
        assert_eq!(rig.mem.borrow_mut().read_f32(0x100), 3.0);
    }

    #[test]
    fn wb_write_hit_stays_in_l2_until_fence() {
        let script = vec![(0, rd(1, 0x100)), (5000, wr(2, 0x100, 9.0))];
        let mut rig = build(WritePolicy::WriteBack, 256 << 10, script);
        rig.engine.run_to_completion();
        // Dirty in L2, NOT in memory yet.
        assert_eq!(rig.mem.borrow_mut().read_f32(0x100), 0.0);
        let l2s = rig.engine.downcast::<PlainL2>(rig.l2).stats;
        // One fill read; the write generated no MM traffic.
        assert_eq!(l2s.reqs_down, 1);
        // Fence drains the dirty line.
        rig.engine.post(100_000, rig.l2, Msg::FenceApply { reply_to: rig.prober, logical_max: 0 });
        rig.engine.post(100_000, rig.l1, Msg::FenceApply { reply_to: rig.prober, logical_max: 0 });
        rig.engine.run_to_completion();
        assert_eq!(rig.mem.borrow_mut().read_f32(0x100), 9.0);
        let l2s = rig.engine.downcast::<PlainL2>(rig.l2).stats;
        assert_eq!(l2s.writebacks, 1);
    }

    #[test]
    fn wb_miss_with_dirty_victim_serializes_eviction() {
        // Tiny L2: 1 KB, 16 ways = 1 set of 16 lines. Dirty 16 lines, then
        // read a 17th: the fill must wait for the victim's write-back.
        let mut script = vec![];
        for i in 0..16u64 {
            script.push((i * 3000, wr(i + 1, 0x1000 + i * 64, i as f32)));
        }
        script.push((100_000, rd(100, 0x8000)));
        let mut rig = build(WritePolicy::WriteBack, 1 << 10, script);
        rig.engine.run_to_completion();
        let l2s = rig.engine.downcast::<PlainL2>(rig.l2).stats;
        assert!(l2s.writebacks >= 1, "dirty victim must be written back");
        // The victim's data must have reached memory.
        let mut found = false;
        for i in 0..16u64 {
            if rig.mem.borrow_mut().read_f32(0x1000 + i * 64) == i as f32 {
                found = true;
            }
        }
        assert!(found, "written-back victim data must be in MM");
    }

    #[test]
    fn wt_vs_wb_transaction_counts() {
        // Streaming writes to distinct lines: WT sends every write to MM;
        // WB (write-allocate) sends one fill per line and no write traffic
        // until eviction/fence.
        let script: Vec<(Cycle, MemReq)> =
            (0..32u64).map(|i| (i * 3000, wr(i + 1, 0x1000 + i * 64, 1.0))).collect();
        let mut wt = build(WritePolicy::WriteThrough, 256 << 10, script.clone());
        wt.engine.run_to_completion();
        let mut wb = build(WritePolicy::WriteBack, 256 << 10, script);
        wb.engine.run_to_completion();
        let wt_tx = wt.engine.downcast::<PlainL2>(wt.l2).stats.down_transactions();
        let wb_tx = wb.engine.downcast::<PlainL2>(wb.l2).stats.down_transactions();
        assert!(
            wt_tx >= wb_tx,
            "WT must produce at least as many L2<->MM transactions ({wt_tx} vs {wb_tx})"
        );
    }

    #[test]
    fn fence_invalidates_l1_so_next_read_refetches() {
        let script = vec![(0, rd(1, 0x200))];
        let mut rig = build(WritePolicy::WriteThrough, 256 << 10, script);
        rig.mem.borrow_mut().write_f32(0x200, 1.0);
        rig.engine.run_to_completion();
        // Mutate MM behind the caches (simulates another GPU's write in an
        // NC system), fence, re-read: must see the new value.
        rig.mem.borrow_mut().write_f32(0x200, 2.0);
        rig.engine.post(50_000, rig.l1, Msg::FenceApply { reply_to: rig.prober, logical_max: 0 });
        rig.engine.post(50_000, rig.l2, Msg::FenceApply { reply_to: rig.prober, logical_max: 0 });
        rig.engine.downcast_mut::<Prober>(rig.prober).script = vec![(60_000, rd(9, 0x200))];
        rig.engine.post(55_000, rig.prober, Msg::Tick);
        rig.engine.run_to_completion();
        let rsps = &rig.engine.downcast::<Prober>(rig.prober).responses;
        let last = rsps.iter().find(|(_, r)| r.id == 9).unwrap();
        assert_eq!(f32_of(&last.1), 2.0);
    }
}
