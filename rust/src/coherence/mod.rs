//! Cache coherence protocols (DESIGN.md S9–S12).
//!
//! Each protocol provides L1/L2 cache *controller* components built on the
//! shared storage substrate (`mem::CacheArray`, `mem::Mshr`):
//!
//! * [`halcone`] — the paper's contribution: cache-level logical clocks
//!   (`cts`), per-block `rts`/`wts` leases, TSU-backed timestamps.
//! * [`none`] — non-coherent baselines (RDMA-WB-NC, SM-WB-NC, SM-WT-NC):
//!   plain WT/WB caches; coherence is the programmer's problem, modelled
//!   by flush+invalidate fences at kernel boundaries.
//! * [`hmg`] — the HMG comparator: VI protocol with a home-node directory
//!   and explicit invalidations over the inter-GPU fabric.
//! * [`tsproto`] — the timestamp-protocol framework: the shared lease /
//!   logical-clock / rollover machinery parameterized by
//!   [`tsproto::TsPolicy`], which the HALCONE controllers and the TSU
//!   consult to additionally speak `tardis` (stable per-line write
//!   timestamps, renewable read leases) and `hlc` (hybrid
//!   physical+logical clocks). See docs/PROTOCOLS.md.
//!
//! The G-TSC traffic ablation (E10) is the `carry_warpts` flag on the
//! HALCONE controllers: it re-adds the CU-level timestamp to every
//! request's wire format, reproducing the traffic HALCONE's cache-level
//! counters eliminate.

pub mod halcone;
pub mod hmg;
pub mod none;
pub mod tsproto;

use std::collections::HashMap;

use crate::mem::AddrMap;
use crate::sim::{CompId, LinkId};

/// Request id used by L1 write-combining flushes. Must stay *below* the
/// L2 controllers' reserved write-back id space (`1 << 62`): flush
/// requests travel to the MM and their responses must retire normal L2
/// MSHR entries, not be mistaken for L2-generated write-back acks.
pub const FLUSH_REQ_ID: u64 = 1 << 61;

/// Per-line timestamp metadata (HALCONE).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TsMeta {
    pub rts: u64,
    pub wts: u64,
}

/// L2\$ write policy (paper §4.1: WT vs WB comparison).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WritePolicy {
    WriteThrough,
    WriteBack,
}

/// Routing used by an L1 controller to reach L2 banks.
///
/// Local banks are reached over per-bank on-chip links; remote banks
/// (RDMA-NC only: L1 -> switch -> remote GPU's L2, Fig. 1) go through the
/// PCIe switch hop.
#[derive(Clone, Debug)]
pub struct L1Routes {
    pub map: AddrMap,
    pub gpu: u32,
    /// Per-local-bank on-chip links (index = bank).
    pub local_links: Vec<LinkId>,
    /// Per-local-bank component ids (index = bank).
    pub local_banks: Vec<CompId>,
    /// Hop toward the inter-GPU switch, when remote access is allowed.
    pub remote_hop: Option<(LinkId, CompId)>,
    /// `[gpu][bank]` component ids for every L2 bank in the system.
    pub all_banks: Vec<Vec<CompId>>,
}

impl L1Routes {
    /// Resolve `addr` to (first-hop link, first-hop component, final dst).
    pub fn route(&self, addr: u64) -> (LinkId, CompId, CompId) {
        let bank = self.map.l2_bank_of(addr) as usize;
        if self.map.is_local(self.gpu, addr) || self.remote_hop.is_none() {
            (self.local_links[bank], self.local_banks[bank], self.local_banks[bank])
        } else {
            let (link, sw) = self.remote_hop.unwrap();
            let home = self.map.home_gpu(addr) as usize;
            (link, sw, self.all_banks[home][bank])
        }
    }
}

/// Routing used by an L2 controller.
#[derive(Clone, Debug)]
pub struct L2Routes {
    pub map: AddrMap,
    pub gpu: u32,
    /// Hop toward main memory (per-GPU uplink into the switch complex, or
    /// the local memory network under RDMA).
    pub mm_hop: (LinkId, CompId),
    /// Memory controller component ids, indexed by global stack.
    pub mcs: Vec<CompId>,
    /// Upstream routes back to requesters (L1s on-chip; remote requesters
    /// fall back to `up_default`, the inter-GPU switch).
    pub up_routes: HashMap<CompId, LinkId>,
    pub up_default: Option<(LinkId, CompId)>,
    /// Peer L2 banks `[gpu][bank]` + hop toward them (HMG).
    pub peer_hop: Option<(LinkId, CompId)>,
    pub all_banks: Vec<Vec<CompId>>,
}

impl L2Routes {
    /// Route toward the MC owning `addr`.
    pub fn route_mm(&self, addr: u64) -> (LinkId, CompId, CompId) {
        let mc = self.mcs[self.map.stack_of(addr) as usize];
        (self.mm_hop.0, self.mm_hop.1, mc)
    }

    /// Route a response (or forwarded request) up to `requester`.
    pub fn route_up(&self, requester: CompId) -> (LinkId, CompId) {
        if let Some(&link) = self.up_routes.get(&requester) {
            (link, requester)
        } else {
            self.up_default
                .unwrap_or_else(|| panic!("no upstream route to {requester:?}"))
        }
    }

    /// Route toward a peer L2 bank (HMG home / sharer traffic).
    pub fn route_peer(&self, gpu: u32, bank: u32) -> (LinkId, CompId, CompId) {
        let (link, sw) = self.peer_hop.expect("peer routing not configured");
        (link, sw, self.all_banks[gpu as usize][bank as usize])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::addr::Topology;

    fn map(topology: Topology) -> AddrMap {
        AddrMap::new(topology, 2, 2, 2, 1 << 20)
    }

    #[test]
    fn l1_routes_local_by_bank() {
        let r = L1Routes {
            map: map(Topology::SharedMem),
            gpu: 0,
            local_links: vec![LinkId(0), LinkId(1)],
            local_banks: vec![CompId(10), CompId(11)],
            remote_hop: None,
            all_banks: vec![vec![CompId(10), CompId(11)], vec![CompId(20), CompId(21)]],
        };
        // line 0 -> bank 0; line 1 (addr 64) -> bank 1.
        assert_eq!(r.route(0), (LinkId(0), CompId(10), CompId(10)));
        assert_eq!(r.route(64), (LinkId(1), CompId(11), CompId(11)));
    }

    #[test]
    fn l1_routes_remote_partition_through_switch() {
        let r = L1Routes {
            map: map(Topology::Rdma),
            gpu: 0,
            local_links: vec![LinkId(0), LinkId(1)],
            local_banks: vec![CompId(10), CompId(11)],
            remote_hop: Some((LinkId(9), CompId(99))),
            all_banks: vec![vec![CompId(10), CompId(11)], vec![CompId(20), CompId(21)]],
        };
        // Address in GPU1's partition, bank 1.
        let addr = (1 << 20) + 64;
        assert_eq!(r.route(addr), (LinkId(9), CompId(99), CompId(21)));
        // Local address stays on-chip.
        assert_eq!(r.route(64), (LinkId(1), CompId(11), CompId(11)));
    }

    #[test]
    fn l2_route_up_falls_back_to_switch() {
        let mut up = HashMap::new();
        up.insert(CompId(3), LinkId(5));
        let r = L2Routes {
            map: map(Topology::SharedMem),
            gpu: 0,
            mm_hop: (LinkId(0), CompId(50)),
            mcs: vec![CompId(60), CompId(61), CompId(62), CompId(63)],
            up_routes: up,
            up_default: Some((LinkId(7), CompId(99))),
            peer_hop: None,
            all_banks: vec![],
        };
        assert_eq!(r.route_up(CompId(3)), (LinkId(5), CompId(3)));
        assert_eq!(r.route_up(CompId(44)), (LinkId(7), CompId(99)));
    }
}
