//! Minimal benchmark statistics harness (criterion is unavailable in the
//! offline registry — DESIGN.md S21).
//!
//! Used by the `rust/benches/*` binaries (`harness = false`): warm up,
//! run `iters` timed iterations, report median and MAD. Simulation
//! experiments are deterministic, so a handful of iterations suffices for
//! host-time numbers; simulated-cycle outputs are exact.

use std::time::Instant;

/// Result of a timed measurement.
#[derive(Clone, Copy, Debug)]
pub struct Measurement {
    pub median_s: f64,
    /// Median absolute deviation.
    pub mad_s: f64,
    pub iters: usize,
}

/// Time `f` for `iters` iterations after `warmup` unrecorded runs.
pub fn measure<T>(warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> Measurement {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples: Vec<f64> = (0..iters.max(1))
        .map(|_| {
            let t0 = Instant::now();
            std::hint::black_box(f());
            t0.elapsed().as_secs_f64()
        })
        .collect();
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = samples[samples.len() / 2];
    let mut devs: Vec<f64> = samples.iter().map(|s| (s - median).abs()).collect();
    devs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Measurement { median_s: median, mad_s: devs[devs.len() / 2], iters: samples.len() }
}

/// Fixed-width table printer for bench output (the "same rows the paper
/// reports" requirement): pass header once, then rows.
pub struct Table {
    widths: Vec<usize>,
}

impl Table {
    pub fn new(headers: &[&str], widths: &[usize]) -> Self {
        assert_eq!(headers.len(), widths.len());
        let mut line = String::new();
        for (h, w) in headers.iter().zip(widths) {
            line.push_str(&format!("{h:>w$} ", w = w));
        }
        println!("{line}");
        println!("{}", "-".repeat(line.len()));
        Table { widths: widths.to_vec() }
    }

    pub fn row(&self, cells: &[String]) {
        let mut line = String::new();
        for (c, w) in cells.iter().zip(&self.widths) {
            line.push_str(&format!("{c:>w$} ", w = *w));
        }
        println!("{line}");
    }
}

/// Format a ratio as the paper does ("4.6x").
pub fn fmt_x(r: f64) -> String {
    format!("{r:.2}x")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_returns_positive_median() {
        let m = measure(1, 5, || (0..1000u64).sum::<u64>());
        assert!(m.median_s >= 0.0);
        assert_eq!(m.iters, 5);
    }

    #[test]
    fn fmt_x_two_decimals() {
        assert_eq!(fmt_x(4.6), "4.60x");
        assert_eq!(fmt_x(0.168), "0.17x");
    }
}
