//! Per-tenant observability (docs/TENANCY.md): tenant-indexed traffic
//! attribution tables, kernel-turnaround aggregation and the Jain
//! fairness index.
//!
//! Attribution mirrors the untagged counters exactly: every site that
//! bumps `CuStats::loads/stores` or an L1's
//! `CacheCtrlStats::hits/misses/coherency_misses` on the CU-request path
//! also bumps the tenant slot of the request's `TenantId`, so per-tenant
//! counts always sum to the untagged totals (the fold-conservation
//! property `rust/tests/tenancy.rs` gates).

/// Per-tenant CU-side issue counters (mirrors the `CuStats` bump sites).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TenantCuStats {
    pub loads: u64,
    pub stores: u64,
    /// Payload bytes the CU requested (loads) or sent (stores).
    pub bytes: u64,
}

/// Per-tenant L1 lookup outcomes (mirrors the `CacheCtrlStats`
/// hit/miss/coherency-miss bump sites at the CU-request entry).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TenantCacheStats {
    pub hits: u64,
    pub misses: u64,
    pub coherency_misses: u64,
}

/// Growable tenant-indexed counter table kept by each L1 controller.
/// Indexing by `TenantId` grows the table on demand, so controllers need
/// no up-front knowledge of the mix width; single-tenant runs cost one
/// slot.
#[derive(Clone, Debug, Default)]
pub struct TenantTraffic {
    slots: Vec<TenantCacheStats>,
}

impl TenantTraffic {
    pub fn slot(&mut self, tenant: u32) -> &mut TenantCacheStats {
        let i = tenant as usize;
        if i >= self.slots.len() {
            self.slots.resize(i + 1, TenantCacheStats::default());
        }
        &mut self.slots[i]
    }

    pub fn get(&self, tenant: u32) -> TenantCacheStats {
        self.slots.get(tenant as usize).copied().unwrap_or_default()
    }

    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    pub fn accumulate(&mut self, o: &TenantTraffic) {
        for (t, s) in o.slots.iter().enumerate() {
            let mine = self.slot(t as u32);
            mine.hits += s.hits;
            mine.misses += s.misses;
            mine.coherency_misses += s.coherency_misses;
        }
    }

    /// Serialize the table for a snapshot (docs/SNAPSHOT.md).
    pub fn save_state(&self, out: &mut Vec<u8>) {
        use crate::snapshot::format::put;
        put(out, self.slots.len() as u64);
        for s in &self.slots {
            put(out, s.hits);
            put(out, s.misses);
            put(out, s.coherency_misses);
        }
    }

    /// Restore the table written by [`TenantTraffic::save_state`].
    pub fn load_state(&mut self, cur: &mut crate::snapshot::format::Cur) -> Result<(), String> {
        let n = cur.u64("tenant slot count")? as usize;
        if n > cur.b.len() {
            return Err(format!("tenant slot count {n} exceeds the input size"));
        }
        self.slots.clear();
        for _ in 0..n {
            self.slots.push(TenantCacheStats {
                hits: cur.u64("tenant hits")?,
                misses: cur.u64("tenant misses")?,
                coherency_misses: cur.u64("tenant coherency_misses")?,
            });
        }
        Ok(())
    }
}

/// One tenant's aggregated view of a finished mix run.
#[derive(Clone, Debug, Default)]
pub struct TenantMetrics {
    pub tenant: u32,
    pub name: String,
    /// Kernels of this tenant that ran to completion.
    pub jobs: u64,
    /// Sum of kernel turnarounds (finish - arrival), in cycles.
    pub turnaround_sum: u64,
    /// Nearest-rank p99 of the kernel turnarounds, in cycles.
    pub turnaround_p99: u64,
    pub loads: u64,
    pub stores: u64,
    /// CU-issued payload bytes (the memory-traffic share numerator).
    pub cu_bytes: u64,
    pub l1_hits: u64,
    pub l1_misses: u64,
    /// L1 lease-expiry/invalidation refetches (the coherence-traffic
    /// share numerator).
    pub l1_coherency_misses: u64,
}

impl TenantMetrics {
    /// Mean kernel turnaround in cycles (0.0 for a job-less tenant).
    pub fn turnaround_mean(&self) -> f64 {
        if self.jobs == 0 {
            0.0
        } else {
            self.turnaround_sum as f64 / self.jobs as f64
        }
    }
}

/// The per-tenant section of [`super::RunMetrics`]; present only for
/// mix (`mix:`) runs so canonical artifacts of ordinary runs keep their
/// exact bytes.
#[derive(Clone, Debug, Default)]
pub struct TenancyReport {
    /// Scheduler policy that produced the run ("fifo" / "rr").
    pub scheduler: String,
    pub tenants: Vec<TenantMetrics>,
}

impl TenancyReport {
    /// Jain fairness index over the tenants' mean turnarounds.
    pub fn jain_turnaround(&self) -> f64 {
        let means: Vec<f64> = self.tenants.iter().map(|t| t.turnaround_mean()).collect();
        jain(&means)
    }

    /// `tenant`'s share of CU-issued payload bytes (0.0 if none moved).
    pub fn mem_traffic_share(&self, tenant: u32) -> f64 {
        let total: u64 = self.tenants.iter().map(|t| t.cu_bytes).sum();
        share(self.tenant(tenant).map_or(0, |t| t.cu_bytes), total)
    }

    /// `tenant`'s share of L1 coherency misses (0.0 if none occurred).
    pub fn coherence_traffic_share(&self, tenant: u32) -> f64 {
        let total: u64 = self.tenants.iter().map(|t| t.l1_coherency_misses).sum();
        share(self.tenant(tenant).map_or(0, |t| t.l1_coherency_misses), total)
    }

    fn tenant(&self, tenant: u32) -> Option<&TenantMetrics> {
        self.tenants.iter().find(|t| t.tenant == tenant)
    }
}

fn share(part: u64, total: u64) -> f64 {
    if total == 0 {
        0.0
    } else {
        part as f64 / total as f64
    }
}

/// Jain fairness index `(Σx)² / (n·Σx²)`: 1.0 when every tenant gets an
/// equal allocation, approaching `1/n` when one tenant hogs everything.
/// Degenerate inputs (empty, or all-zero) read as perfectly fair.
pub fn jain(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 1.0;
    }
    let sum: f64 = xs.iter().sum();
    let sq: f64 = xs.iter().map(|x| x * x).sum();
    if sq == 0.0 {
        return 1.0;
    }
    sum * sum / (xs.len() as f64 * sq)
}

/// Nearest-rank 99th percentile of an ascending-sorted sample.
pub fn p99_sorted(sorted: &[u64]) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let n = sorted.len();
    // ceil(0.99 * n), 1-based rank; integer arithmetic keeps it exact.
    let rank = (99 * n).div_ceil(100).max(1);
    sorted[rank - 1]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn jain_all_equal_is_one() {
        assert!((jain(&[5.0, 5.0, 5.0, 5.0]) - 1.0).abs() < 1e-12);
        assert!((jain(&[1.0]) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn jain_one_hog_approaches_one_over_n() {
        // One tenant with everything, three with nothing: exactly 1/4.
        let j = jain(&[100.0, 0.0, 0.0, 0.0]);
        assert!((j - 0.25).abs() < 1e-12, "{j}");
        // Mild skew sits strictly between 1/n and 1.
        let j = jain(&[1.0, 2.0, 3.0, 4.0]);
        assert!(j > 0.25 && j < 1.0, "{j}");
    }

    #[test]
    fn jain_degenerate_inputs_read_fair() {
        assert_eq!(jain(&[]), 1.0);
        assert_eq!(jain(&[0.0, 0.0]), 1.0);
    }

    #[test]
    fn p99_is_nearest_rank() {
        assert_eq!(p99_sorted(&[]), 0);
        assert_eq!(p99_sorted(&[7]), 7);
        assert_eq!(p99_sorted(&[1, 2]), 2);
        let v: Vec<u64> = (1..=100).collect();
        assert_eq!(p99_sorted(&v), 99);
        let v: Vec<u64> = (1..=200).collect();
        assert_eq!(p99_sorted(&v), 198);
    }

    #[test]
    fn traffic_table_grows_and_accumulates() {
        let mut a = TenantTraffic::default();
        a.slot(2).hits += 3;
        a.slot(0).misses += 1;
        assert_eq!(a.len(), 3);
        assert_eq!(a.get(2).hits, 3);
        assert_eq!(a.get(9), TenantCacheStats::default());
        let mut b = TenantTraffic::default();
        b.slot(2).hits += 4;
        b.slot(3).coherency_misses += 5;
        a.accumulate(&b);
        assert_eq!(a.get(2).hits, 7);
        assert_eq!(a.get(3).coherency_misses, 5);
        assert_eq!(a.len(), 4);
    }

    #[test]
    fn report_shares_split_the_totals() {
        let rep = TenancyReport {
            scheduler: "fifo".into(),
            tenants: vec![
                TenantMetrics {
                    tenant: 0,
                    jobs: 2,
                    turnaround_sum: 200,
                    cu_bytes: 300,
                    l1_coherency_misses: 9,
                    ..Default::default()
                },
                TenantMetrics {
                    tenant: 1,
                    jobs: 1,
                    turnaround_sum: 100,
                    cu_bytes: 100,
                    l1_coherency_misses: 3,
                    ..Default::default()
                },
            ],
        };
        assert!((rep.mem_traffic_share(0) - 0.75).abs() < 1e-12);
        assert!((rep.mem_traffic_share(1) - 0.25).abs() < 1e-12);
        assert!((rep.coherence_traffic_share(0) - 0.75).abs() < 1e-12);
        // Equal mean turnarounds (100 each): perfectly fair.
        assert!((rep.jain_turnaround() - 1.0).abs() < 1e-12);
        // Absent tenant shares nothing.
        assert_eq!(rep.mem_traffic_share(7), 0.0);
    }
}
