//! Replay divergence report: the per-access regression oracle.
//!
//! Aggregate cycle counts can agree by accident; two traces of the same
//! logical run cannot. [`diff_traces`] compares a recording against a
//! replay's re-recording (or any two traces) at three severities:
//!
//! * **shape** — stream geometry (GPU/CU counts, per-wavefront record
//!   counts);
//! * **structural** — per-record (phase, kind, addr, size, gap), aligned
//!   **per wavefront**: a wavefront's records are in program order on
//!   both sides, while the CU-level interleaving *across* wavefronts is
//!   a scheduling artifact (synthetic traces are written in program
//!   order, re-recordings in execution order). Any mismatch means the
//!   replayed access stream is not the recorded one;
//! * **timing** — per-record issue cycles plus the recorded run totals:
//!   mismatches mean the stream was re-injected but scheduled
//!   differently (a faithful-stream interleaving change always shows up
//!   here).
//!
//! The CI golden-trace gate records at `--shards 1`, replays at
//! `--shards 4` and fails on *any* divergence ([`DivergenceReport::identical`]).

use std::collections::BTreeMap;

use crate::trace::{Trace, TraceOp};

/// Outcome of comparing two traces (`a` = baseline, `b` = candidate).
#[derive(Debug, Default)]
pub struct DivergenceReport {
    /// Geometry/record-count mismatch, if any (first one found).
    pub shape_mismatch: Option<String>,
    /// Total records in the baseline / candidate.
    pub records_a: u64,
    pub records_b: u64,
    /// Records compared pairwise (the overlap on shape mismatch).
    pub compared: u64,
    /// Records whose (phase, kind, addr, size, gap) differ within their
    /// wavefront-aligned position.
    pub structural_mismatches: u64,
    pub first_structural: Option<String>,
    /// Structurally equal records whose issue cycle differs.
    pub cycle_mismatches: u64,
    pub max_cycle_delta: u64,
    pub first_cycle: Option<String>,
    /// Recorded end-to-end cycles (0 = unknown, e.g. synthetic traces).
    pub cycles: (u64, u64),
    /// Recorded engine event totals (0 = unknown).
    pub events: (u64, u64),
}

impl DivergenceReport {
    /// The candidate re-issued exactly the baseline's access stream
    /// (shape + structure), ignoring timing.
    pub fn structural_identical(&self) -> bool {
        self.shape_mismatch.is_none() && self.structural_mismatches == 0
    }

    /// Zero divergence: identical streams, identical per-access issue
    /// cycles, and identical run totals where both sides recorded them.
    pub fn identical(&self) -> bool {
        self.structural_identical()
            && self.cycle_mismatches == 0
            && (self.cycles.0 == 0 || self.cycles.1 == 0 || self.cycles.0 == self.cycles.1)
            && (self.events.0 == 0 || self.events.1 == 0 || self.events.0 == self.events.1)
    }

    /// Multi-line human rendering.
    pub fn describe(&self) -> String {
        let mut out = String::new();
        if let Some(s) = &self.shape_mismatch {
            out.push_str(&format!("SHAPE: {s}\n"));
        }
        if let Some(s) = &self.first_structural {
            out.push_str(&format!(
                "STRUCTURE: {} of {} records diverge; first at {s}\n",
                self.structural_mismatches, self.compared
            ));
        }
        if let Some(s) = &self.first_cycle {
            out.push_str(&format!(
                "TIMING: {} records issue at different cycles (max delta {}); first at {s}\n",
                self.cycle_mismatches, self.max_cycle_delta
            ));
        }
        if self.cycles.0 != 0 && self.cycles.1 != 0 && self.cycles.0 != self.cycles.1 {
            out.push_str(&format!(
                "TOTALS: end-to-end cycles {} -> {}\n",
                self.cycles.0, self.cycles.1
            ));
        }
        if self.events.0 != 0 && self.events.1 != 0 && self.events.0 != self.events.1 {
            out.push_str(&format!(
                "TOTALS: engine events {} -> {}\n",
                self.events.0, self.events.1
            ));
        }
        let verdict = if self.identical() {
            "IDENTICAL".to_string()
        } else if self.structural_identical() {
            "STREAM OK, TIMING DIVERGED".to_string()
        } else {
            "DIVERGED".to_string()
        };
        out.push_str(&format!(
            "divergence: {verdict} ({} baseline / {} candidate records, {} compared)",
            self.records_a, self.records_b, self.compared
        ));
        out
    }
}

fn structural_key(op: &TraceOp) -> (u32, crate::trace::TraceKind, u64, u32, u64) {
    (op.phase, op.kind, op.addr, op.size, op.gap)
}

/// Bucket a CU stream by wavefront, preserving each wavefront's record
/// order (program order on both sides).
fn by_wavefront(ops: &[TraceOp]) -> BTreeMap<u32, Vec<&TraceOp>> {
    let mut out: BTreeMap<u32, Vec<&TraceOp>> = BTreeMap::new();
    for op in ops {
        out.entry(op.wf).or_default().push(op);
    }
    out
}

/// Compare two traces record by record, aligned per wavefront.
pub fn diff_traces(a: &Trace, b: &Trace) -> DivergenceReport {
    let mut rep = DivergenceReport {
        records_a: a.total_records(),
        records_b: b.total_records(),
        cycles: (a.meta.cycles, b.meta.cycles),
        events: (a.meta.events, b.meta.events),
        ..Default::default()
    };
    if a.streams.len() != b.streams.len() {
        rep.shape_mismatch =
            Some(format!("{} vs {} GPU streams", a.streams.len(), b.streams.len()));
    }
    let shape = |msg: &mut Option<String>, text: String| {
        if msg.is_none() {
            *msg = Some(text);
        }
    };
    for (g, (ga, gb)) in a.streams.iter().zip(&b.streams).enumerate() {
        if ga.len() != gb.len() {
            shape(
                &mut rep.shape_mismatch,
                format!("gpu{g}: {} vs {} CU streams", ga.len(), gb.len()),
            );
        }
        for (c, (ca, cb)) in ga.iter().zip(gb).enumerate() {
            let (wa, wb) = (by_wavefront(ca), by_wavefront(cb));
            let wfs: std::collections::BTreeSet<u32> =
                wa.keys().chain(wb.keys()).copied().collect();
            for wf in wfs {
                let empty = Vec::new();
                let la = wa.get(&wf).unwrap_or(&empty);
                let lb = wb.get(&wf).unwrap_or(&empty);
                if la.len() != lb.len() {
                    shape(
                        &mut rep.shape_mismatch,
                        format!(
                            "gpu{g}.cu{c} wf{wf}: {} vs {} records",
                            la.len(),
                            lb.len()
                        ),
                    );
                }
                for (i, (oa, ob)) in la.iter().copied().zip(lb.iter().copied()).enumerate() {
                    rep.compared += 1;
                    if structural_key(oa) != structural_key(ob) {
                        rep.structural_mismatches += 1;
                        if rep.first_structural.is_none() {
                            rep.first_structural = Some(format!(
                                "gpu{g}.cu{c} wf{wf} record {i}: {oa:?} vs {ob:?}"
                            ));
                        }
                    } else if oa.cycle != ob.cycle {
                        rep.cycle_mismatches += 1;
                        let delta = oa.cycle.abs_diff(ob.cycle);
                        rep.max_cycle_delta = rep.max_cycle_delta.max(delta);
                        if rep.first_cycle.is_none() {
                            rep.first_cycle = Some(format!(
                                "gpu{g}.cu{c} wf{wf} record {i}: cycle {} vs {}",
                                oa.cycle, ob.cycle
                            ));
                        }
                    }
                }
            }
        }
    }
    rep
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{TraceKind, TraceMeta, TraceOp};

    fn trace(cycle0: u64, cycles: u64) -> Trace {
        Trace {
            meta: TraceMeta {
                workload: "t".into(),
                n_gpus: 1,
                cus_per_gpu: 1,
                wavefronts_per_cu: 1,
                n_phases: 1,
                gpu_mem_bytes: 1 << 20,
                cycles,
                events: 10,
                init: vec![],
            },
            streams: vec![vec![vec![
                TraceOp {
                    phase: 0,
                    wf: 0,
                    kind: TraceKind::Load,
                    addr: 0x40,
                    size: 64,
                    gap: 1,
                    cycle: cycle0,
                },
                TraceOp {
                    phase: 0,
                    wf: 0,
                    kind: TraceKind::End,
                    addr: 0,
                    size: 0,
                    gap: 0,
                    cycle: cycle0 + 5,
                },
            ]]],
        }
    }

    #[test]
    fn identical_traces_report_identical() {
        let a = trace(3, 100);
        let rep = diff_traces(&a, &a.clone());
        assert!(rep.identical());
        assert_eq!(rep.compared, 2);
        assert!(rep.describe().contains("IDENTICAL"));
    }

    #[test]
    fn cycle_shift_is_timing_divergence_not_structural() {
        let rep = diff_traces(&trace(3, 100), &trace(4, 100));
        assert!(rep.structural_identical());
        assert!(!rep.identical());
        assert_eq!(rep.cycle_mismatches, 2);
        assert_eq!(rep.max_cycle_delta, 1);
        assert!(rep.describe().contains("TIMING"));
    }

    #[test]
    fn address_change_is_structural() {
        let a = trace(3, 100);
        let mut b = a.clone();
        b.streams[0][0][0].addr = 0x80;
        let rep = diff_traces(&a, &b);
        assert!(!rep.structural_identical());
        assert_eq!(rep.structural_mismatches, 1);
        assert!(rep.first_structural.as_deref().unwrap().contains("record 0"));
    }

    #[test]
    fn total_cycle_drift_fails_unless_unknown() {
        let rep = diff_traces(&trace(3, 100), &trace(3, 101));
        assert!(!rep.identical());
        assert!(rep.describe().contains("TOTALS"));
        // Synthetic baselines (cycles = 0) skip the totals comparison.
        let rep = diff_traces(&trace(3, 0), &trace(3, 101));
        assert!(rep.identical());
    }

    #[test]
    fn wavefront_interleaving_is_not_structural_divergence() {
        // Program-ordered (synthetic) vs execution-ordered (re-recorded)
        // CU streams: same per-wavefront sequences, different CU-level
        // interleaving. The per-wavefront alignment must see through it.
        let op = |wf: u32, addr: u64, cycle: u64| TraceOp {
            phase: 0,
            wf,
            kind: TraceKind::Load,
            addr,
            size: 64,
            gap: 0,
            cycle,
        };
        let mut a = trace(0, 0);
        a.streams[0][0] = vec![op(0, 0x40, 0), op(0, 0x80, 0), op(1, 0xc0, 0), op(1, 0x100, 0)];
        let mut b = trace(0, 0);
        b.streams[0][0] = vec![op(0, 0x40, 1), op(1, 0xc0, 2), op(0, 0x80, 3), op(1, 0x100, 4)];
        let rep = diff_traces(&a, &b);
        assert!(rep.structural_identical(), "{}", rep.describe());
        assert_eq!(rep.compared, 4);
        // Timing still differs record by record (synthetic side is 0).
        assert_eq!(rep.cycle_mismatches, 4);
    }

    #[test]
    fn shape_mismatch_is_reported() {
        let a = trace(3, 100);
        let mut b = a.clone();
        b.streams[0][0].pop();
        let rep = diff_traces(&a, &b);
        assert!(rep.shape_mismatch.is_some());
        assert!(!rep.identical());
        assert_eq!(rep.compared, 1);
    }
}
