//! Metrics: per-controller counters and whole-run aggregation.
//!
//! The paper's evaluation reports three quantity families:
//! runtime (speed-up), L2\$<->MM transaction counts (Fig. 7b, 8c) and
//! L1\$<->L2\$ transaction counts (Fig. 7c). Every cache controller and
//! memory controller keeps a [`CacheCtrlStats`]/`MemCtrlStats`; the
//! coordinator sweeps them into a [`RunMetrics`] after the run.

pub mod bench;
pub mod divergence;
pub mod tenancy;

/// Counters kept by every cache controller (L1 and L2, all protocols).
#[derive(Clone, Copy, Debug, Default)]
pub struct CacheCtrlStats {
    /// Requests received from the level above (CU for L1, L1 for L2).
    pub reqs_in: u64,
    /// Responses sent back up.
    pub rsps_out: u64,
    /// Requests sent to the level below (L2 for L1, MM for L2).
    pub reqs_down: u64,
    /// Responses received from below.
    pub rsps_down: u64,
    /// Lease-valid (or plain) hits.
    pub hits: u64,
    /// Misses with no tag match (compulsory/capacity/conflict).
    pub misses: u64,
    /// Tag match but lease expired (HALCONE) or invalidated (HMG).
    pub coherency_misses: u64,
    /// Requests merged onto in-flight MSHR entries.
    pub mshr_merges: u64,
    /// Bytes sent downstream (request traffic).
    pub bytes_down: u64,
    /// Bytes sent upstream (response traffic).
    pub bytes_up: u64,
    /// Write-backs issued (WB policies / fences).
    pub writebacks: u64,
    /// HMG: invalidations sent (home) or received (sharer).
    pub invalidations: u64,
}

impl CacheCtrlStats {
    /// Total transactions exchanged with the level below (the paper's
    /// "number of transactions" metric counts requests + responses).
    pub fn down_transactions(&self) -> u64 {
        self.reqs_down + self.rsps_down
    }

    /// Total transactions exchanged with the level above.
    pub fn up_transactions(&self) -> u64 {
        self.reqs_in + self.rsps_out
    }

    pub fn accumulate(&mut self, o: &CacheCtrlStats) {
        self.reqs_in += o.reqs_in;
        self.rsps_out += o.rsps_out;
        self.reqs_down += o.reqs_down;
        self.rsps_down += o.rsps_down;
        self.hits += o.hits;
        self.misses += o.misses;
        self.coherency_misses += o.coherency_misses;
        self.mshr_merges += o.mshr_merges;
        self.bytes_down += o.bytes_down;
        self.bytes_up += o.bytes_up;
        self.writebacks += o.writebacks;
        self.invalidations += o.invalidations;
    }

    /// Serialize every counter for a snapshot (docs/SNAPSHOT.md).
    pub fn save_state(&self, out: &mut Vec<u8>) {
        use crate::snapshot::format::put;
        put(out, self.reqs_in);
        put(out, self.rsps_out);
        put(out, self.reqs_down);
        put(out, self.rsps_down);
        put(out, self.hits);
        put(out, self.misses);
        put(out, self.coherency_misses);
        put(out, self.mshr_merges);
        put(out, self.bytes_down);
        put(out, self.bytes_up);
        put(out, self.writebacks);
        put(out, self.invalidations);
    }

    /// Restore the counters written by [`CacheCtrlStats::save_state`].
    pub fn load_state(&mut self, cur: &mut crate::snapshot::format::Cur) -> Result<(), String> {
        self.reqs_in = cur.u64("stats reqs_in")?;
        self.rsps_out = cur.u64("stats rsps_out")?;
        self.reqs_down = cur.u64("stats reqs_down")?;
        self.rsps_down = cur.u64("stats rsps_down")?;
        self.hits = cur.u64("stats hits")?;
        self.misses = cur.u64("stats misses")?;
        self.coherency_misses = cur.u64("stats coherency_misses")?;
        self.mshr_merges = cur.u64("stats mshr_merges")?;
        self.bytes_down = cur.u64("stats bytes_down")?;
        self.bytes_up = cur.u64("stats bytes_up")?;
        self.writebacks = cur.u64("stats writebacks")?;
        self.invalidations = cur.u64("stats invalidations")?;
        Ok(())
    }
}

/// Counters produced by deterministic fault injection
/// (docs/ROBUSTNESS.md). All pure functions of the fault seed and the
/// simulated configuration, so the section is byte-stable across
/// `--shards`/`--jobs` like everything else in the canonical artifact.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FaultReport {
    /// Cycles link traffic spent waiting out outage windows.
    pub link_outage_cycles: u64,
    /// Messages accepted inside degraded (latency/bandwidth) windows.
    pub link_degraded_msgs: u64,
    /// Conservative full-cache flushes forced by HALCONE `cts` epoch
    /// crossings under the finite-width timestamp mode.
    pub rollover_flushes: u64,
    /// Epoch crossings of the TSUs' memts high-water marks.
    pub tsu_rollovers: u64,
}

/// Whole-run results assembled by the coordinator.
#[derive(Clone, Debug, Default)]
pub struct RunMetrics {
    /// End-to-end simulated cycles (includes copy phases and fences).
    pub cycles: u64,
    /// Events the engine dispatched (simulator perf, not paper metric).
    pub events: u64,
    /// Wall-clock seconds the simulation took (simulator perf).
    pub host_seconds: f64,
    /// Engine throughput, `events / host_seconds` (simulator perf; 0 when
    /// timing was not captured). Host-dependent: excluded from canonical
    /// artifacts, recorded in full ones so the perf trajectory
    /// accumulates (docs/PERF.md).
    pub events_per_sec: f64,
    /// Message boxes taken from the allocator / served from the free
    /// lists, summed over the engine's logical shards (simulator perf:
    /// the zero-alloc discipline of docs/PERF.md). Deterministic, but
    /// engine-internal — kept out of the canonical artifact with the
    /// other host-perf fields.
    pub pool_fresh_boxes: u64,
    pub pool_reused_boxes: u64,
    /// Per-shard occupancy profile: events dispatched, windows entered
    /// and windows entered-but-idle for each logical engine shard
    /// (index = shard id; the hub is the last entry). Deterministic but
    /// engine-internal — host-only like the pool counters. Feeds the
    /// profile-guided `shard_groups` rebalancing
    /// (`coordinator::topology::plan_shard_groups`).
    pub shard_events: Vec<u64>,
    pub shard_windows: Vec<u64>,
    pub shard_idle_windows: Vec<u64>,
    /// CU-issued loads / stores (per-op throughput denominators for
    /// campaign artifacts).
    pub cu_loads: u64,
    pub cu_stores: u64,
    /// Aggregated L1 controller stats.
    pub l1: CacheCtrlStats,
    /// Aggregated L2 controller stats.
    pub l2: CacheCtrlStats,
    /// MM reads + writes served.
    pub mm_reads: u64,
    pub mm_writes: u64,
    /// TSU counters (0 when coherence is off).
    pub tsu_lookups: u64,
    pub tsu_evictions: u64,
    /// Bytes moved over inter-GPU / PCIe links (RDMA configs).
    pub pcie_bytes: u64,
    /// Bytes moved L2<->MM.
    pub mem_bytes: u64,
    /// Per-tenant section, populated only for multi-tenant (`mix:`) runs
    /// — `None` keeps ordinary runs' canonical artifacts byte-stable.
    pub tenancy: Option<tenancy::TenancyReport>,
    /// Fault-injection section, populated only when a fault schedule is
    /// active — `None` keeps fault-free canonical artifacts byte-stable.
    pub faults: Option<FaultReport>,
}

impl RunMetrics {
    /// Paper Fig. 7(b): L2$ <-> MM transactions.
    pub fn l2_mm_transactions(&self) -> u64 {
        self.l2.down_transactions()
    }

    /// Paper Fig. 7(c): L1$ <-> L2$ transactions.
    pub fn l1_l2_transactions(&self) -> u64 {
        self.l1.down_transactions()
    }

    /// Total CU-issued memory operations.
    pub fn cu_ops(&self) -> u64 {
        self.cu_loads + self.cu_stores
    }

    /// Simulated cycles per CU memory op (`None` for an op-free run).
    pub fn cycles_per_op(&self) -> Option<f64> {
        let ops = self.cu_ops();
        if ops == 0 {
            return None;
        }
        Some(self.cycles as f64 / ops as f64)
    }

    /// Speed-up of `self` relative to a baseline run. `None` when either
    /// run recorded zero cycles — a degenerate cell would otherwise
    /// yield a silent `inf`/`NaN` in reports.
    pub fn speedup_vs(&self, baseline: &RunMetrics) -> Option<f64> {
        if self.cycles == 0 || baseline.cycles == 0 {
            return None;
        }
        Some(baseline.cycles as f64 / self.cycles as f64)
    }

    /// Fill `events_per_sec` from `events` and `host_seconds` (guarding
    /// the degenerate zero-time case).
    pub fn finalize_host_perf(&mut self) {
        self.events_per_sec = if self.host_seconds > 0.0 {
            self.events as f64 / self.host_seconds
        } else {
            0.0
        };
    }
}

/// Geometric mean (the paper's "Mean" bars).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let s: f64 = xs.iter().map(|x| x.ln()).sum();
    (s / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transactions_sum_reqs_and_rsps() {
        let s = CacheCtrlStats { reqs_down: 10, rsps_down: 8, ..Default::default() };
        assert_eq!(s.down_transactions(), 18);
    }

    #[test]
    fn accumulate_adds_fieldwise() {
        let mut a = CacheCtrlStats { hits: 1, misses: 2, ..Default::default() };
        let b = CacheCtrlStats { hits: 10, coherency_misses: 5, ..Default::default() };
        a.accumulate(&b);
        assert_eq!(a.hits, 11);
        assert_eq!(a.misses, 2);
        assert_eq!(a.coherency_misses, 5);
    }

    #[test]
    fn speedup_is_baseline_over_self() {
        let fast = RunMetrics { cycles: 100, ..Default::default() };
        let slow = RunMetrics { cycles: 460, ..Default::default() };
        assert!((fast.speedup_vs(&slow).unwrap() - 4.6).abs() < 1e-9);
    }

    #[test]
    fn zero_cycle_runs_have_no_speedup() {
        let zero = RunMetrics { cycles: 0, ..Default::default() };
        let some = RunMetrics { cycles: 100, ..Default::default() };
        assert_eq!(some.speedup_vs(&zero), None);
        assert_eq!(zero.speedup_vs(&some), None);
        assert_eq!(zero.speedup_vs(&zero), None);
    }

    #[test]
    fn host_perf_finalizes_safely() {
        let mut m = RunMetrics { events: 1000, host_seconds: 0.5, ..Default::default() };
        m.finalize_host_perf();
        assert!((m.events_per_sec - 2000.0).abs() < 1e-9);
        let mut z = RunMetrics { events: 1000, host_seconds: 0.0, ..Default::default() };
        z.finalize_host_perf();
        assert_eq!(z.events_per_sec, 0.0);
    }

    #[test]
    fn cu_op_throughput_guards_div_by_zero() {
        let idle = RunMetrics { cycles: 10, ..Default::default() };
        assert_eq!(idle.cycles_per_op(), None);
        let busy = RunMetrics { cycles: 100, cu_loads: 30, cu_stores: 20, ..Default::default() };
        assert_eq!(busy.cu_ops(), 50);
        assert!((busy.cycles_per_op().unwrap() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn geomean_matches_hand_computation() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
        assert!(geomean(&[]).is_nan());
    }
}
