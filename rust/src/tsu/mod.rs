//! Timestamp Storage Unit (paper §3.2.5, Fig. 6).
//!
//! The TSU lives in the logic layer of each HBM stack and tracks the
//! logical lease timestamp (`memts`) of every block handed out to any
//! L2\$. It is consulted *in parallel* with the DRAM access, and its
//! latency (50 cycles, conservatively an L3-hit-like time) is below the
//! memory controller's fixed 100-cycle latency — so it never extends the
//! critical path. The simulator therefore models TSU lookups as free in
//! time but fully accounts occupancy, evictions and the generated
//! timestamps.
//!
//! Design deviation (documented; DESIGN.md §6): the paper evicts TSU
//! entries when the corresponding L2 line is evicted and falls back to
//! lowest-memts eviction when full. We implement the capacity path
//! (8-way set-associative, lowest-memts victim within the set) and, to
//! preserve correctness when an entry is re-created after eviction, new
//! entries start from a monotonic floor (`floor_ts`) rather than 0: a
//! re-created entry can never hand out a lease that overlaps a stale
//! copy's still-valid window.

use crate::coherence::tsproto::{self, TsPolicy};
use crate::sim::msg::TsPair;
use crate::sim::Cycle;

/// Lease lengths in logical time units (paper §5.4 default: Rd=10, Wr=5).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Leases {
    pub rd: u64,
    pub wr: u64,
}

impl Default for Leases {
    fn default() -> Self {
        Leases { rd: 10, wr: 5 }
    }
}

#[derive(Clone, Copy, Debug)]
struct Entry {
    tag: u64,
    /// Read frontier: end of the furthest lease handed out (HALCONE's
    /// `memts`; Tardis' `rts`).
    memts: u64,
    /// Tardis only: the line's stable write timestamp (its version).
    /// Unused — and not serialized — under the other policies, so the
    /// HALCONE snapshot layout is byte-unchanged.
    wts: u64,
}

/// Per-HBM-stack timestamp store.
#[derive(Debug)]
pub struct Tsu {
    sets: u64,
    ways: u32,
    slots: Vec<Option<Entry>>,
    leases: Leases,
    /// Timestamp protocol this TSU serves (docs/PROTOCOLS.md).
    policy: TsPolicy,
    /// Monotonic floor: max memts ever evicted from this TSU.
    floor_ts: u64,
    /// Finite timestamp width (docs/ROBUSTNESS.md); 0 = unbounded.
    ts_bits: u32,
    /// Metrics.
    pub lookups: u64,
    pub inserts: u64,
    pub evictions: u64,
    /// Epoch (2^ts_bits) boundaries crossed by the memts high-water
    /// mark — the hardware rollovers an N-bit TSU would perform.
    pub ts_rollovers: u64,
    /// Highest memts handed out (drives fence logical_max).
    pub max_memts: u64,
}

impl Tsu {
    /// `entries` total capacity; 8-way set-associative (paper §3.2.5).
    pub fn new(entries: u64, leases: Leases) -> Self {
        let ways = 8u32;
        let sets = (entries / ways as u64).next_power_of_two().max(1);
        let mut slots = Vec::new();
        slots.resize_with((sets * ways as u64) as usize, || None);
        Tsu {
            sets,
            ways,
            slots,
            leases,
            policy: TsPolicy::Halcone,
            floor_ts: 0,
            ts_bits: 0,
            lookups: 0,
            inserts: 0,
            evictions: 0,
            ts_rollovers: 0,
            max_memts: 0,
        }
    }

    pub fn leases(&self) -> Leases {
        self.leases
    }

    /// Select the timestamp protocol this TSU speaks (default HALCONE).
    pub fn with_policy(mut self, policy: TsPolicy) -> Self {
        self.policy = policy;
        self
    }

    /// Enable the finite-width timestamp model: count every epoch
    /// (2^bits) crossing of the memts high-water mark. Timestamps stay
    /// monotonic `u64`s in the simulator — the crossing count is the
    /// number of rollovers N-bit hardware would have absorbed.
    pub fn set_ts_bits(&mut self, bits: u32) {
        self.ts_bits = bits;
    }

    /// Track the high-water mark, counting epoch crossings under the
    /// finite-width model.
    fn raise_memts(&mut self, memts: u64) {
        if memts > self.max_memts {
            self.ts_rollovers += crate::faults::epoch_of(memts, self.ts_bits)
                - crate::faults::epoch_of(self.max_memts, self.ts_bits);
            self.max_memts = memts;
        }
    }

    fn set_range(&self, line_addr: u64) -> std::ops::Range<usize> {
        let set = (line_addr / crate::mem::LINE) & (self.sets - 1);
        let start = (set * self.ways as u64) as usize;
        start..start + self.ways as usize
    }

    fn tag(line_addr: u64) -> u64 {
        line_addr / crate::mem::LINE
    }

    /// Serve a read request for `line_addr` at simulated time `now`:
    /// advance the block's read frontier by RdLease and return the
    /// (Mrts, Mwts) pair (paper Alg. 3; per-policy variations in
    /// [`Tsu::advance`]).
    pub fn on_read(&mut self, line_addr: u64, now: Cycle) -> TsPair {
        self.advance(line_addr, self.leases.rd, false, now)
    }

    /// Serve a write request: advance by WrLease.
    pub fn on_write(&mut self, line_addr: u64, now: Cycle) -> TsPair {
        self.advance(line_addr, self.leases.wr, true, now)
    }

    /// The shared lease-grant path, specialized by [`TsPolicy`]:
    ///
    /// * HALCONE — every access moves `memts` forward by the lease and
    ///   reports the previous `memts` as the write timestamp.
    /// * Tardis — reads extend the read frontier without touching the
    ///   line's stable `wts`; writes bump `wts` one past the frontier so
    ///   no outstanding read lease can cover the new version.
    /// * HLC — like HALCONE, but the grant base is floored by coarse
    ///   physical time (`now >> HLC_SHIFT`), keeping hybrid clocks
    ///   within one lease + one tick of wall-clock. `now` is simulated
    ///   time, so the floor is deterministic at any `--shards` level.
    fn advance(&mut self, line_addr: u64, lease: u64, write: bool, now: Cycle) -> TsPair {
        self.lookups += 1;
        let tag = Self::tag(line_addr);
        let range = self.set_range(line_addr);
        let phys = match self.policy {
            TsPolicy::Hlc => tsproto::hlc_phys(now),
            TsPolicy::Halcone | TsPolicy::Tardis => 0,
        };

        // Hit: extend the existing entry.
        if let Some(slot) = self.slots[range.clone()]
            .iter_mut()
            .find(|s| s.as_ref().is_some_and(|e| e.tag == tag))
        {
            let e = slot.as_mut().unwrap();
            let pair = match self.policy {
                TsPolicy::Halcone | TsPolicy::Hlc => {
                    let old = e.memts.max(phys);
                    e.memts = old + lease;
                    TsPair { rts: e.memts, wts: old }
                }
                TsPolicy::Tardis if write => {
                    let wts = e.memts + 1;
                    e.wts = wts;
                    e.memts = wts + lease;
                    TsPair { rts: e.memts, wts }
                }
                TsPolicy::Tardis => {
                    e.memts = e.memts.max(e.wts) + lease;
                    TsPair { rts: e.memts, wts: e.wts }
                }
            };
            self.raise_memts(pair.rts);
            return pair;
        }

        // Miss: allocate, evicting the lowest-memts victim if the set is
        // full. New entries start at the monotonic floor (HLC: floored
        // by coarse physical time too).
        self.inserts += 1;
        let idx = match range.clone().find(|&i| self.slots[i].is_none()) {
            Some(i) => i,
            None => {
                let victim_idx = range
                    .clone()
                    .min_by_key(|&i| self.slots[i].as_ref().unwrap().memts)
                    .unwrap();
                let victim = self.slots[victim_idx].take().unwrap();
                // Re-anchor: the new entry must start above anything
                // evicted, so no re-created lease overlaps a stale copy's
                // still-valid window.
                self.floor_ts = self.floor_ts.max(victim.memts);
                self.evictions += 1;
                victim_idx
            }
        };
        let start_ts = self.floor_ts.max(phys);
        self.slots[idx] = Some(Entry { tag, memts: start_ts + lease, wts: start_ts });
        self.raise_memts(start_ts + lease);
        TsPair { rts: start_ts + lease, wts: start_ts }
    }

    /// Storage bytes for the paper's area accounting (16-bit memts each).
    pub fn occupancy(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    /// Serialize the mutable state (docs/SNAPSHOT.md): every slot, the
    /// monotonic eviction floor and the metric counters. Geometry,
    /// leases and the policy come from the config (which the snapshot
    /// fingerprint pins) and are validated on load. Per-entry `wts` is
    /// written only under Tardis — the other policies never read it, so
    /// their layouts are byte-unchanged from format v2.
    pub fn save_state(&self, out: &mut Vec<u8>) {
        use crate::snapshot::format::put;
        put(out, self.slots.len() as u64);
        for slot in &self.slots {
            match slot {
                None => out.push(0),
                Some(e) => {
                    out.push(1);
                    put(out, e.tag);
                    put(out, e.memts);
                    if self.policy == TsPolicy::Tardis {
                        put(out, e.wts);
                    }
                }
            }
        }
        put(out, self.floor_ts);
        put(out, self.lookups);
        put(out, self.inserts);
        put(out, self.evictions);
        put(out, self.ts_rollovers);
        put(out, self.max_memts);
    }

    /// Restore the state written by [`Tsu::save_state`] into a TSU of
    /// the same geometry.
    pub fn load_state(&mut self, cur: &mut crate::snapshot::format::Cur) -> Result<(), String> {
        let n = cur.u64("tsu slot count")? as usize;
        if n != self.slots.len() {
            return Err(format!(
                "snapshot TSU has {n} slots, this configuration has {} — the \
                 configurations differ",
                self.slots.len()
            ));
        }
        for i in 0..n {
            self.slots[i] = match cur.byte("tsu slot flag")? {
                0 => None,
                1 => {
                    let tag = cur.u64("tsu tag")?;
                    let memts = cur.u64("tsu memts")?;
                    let wts = if self.policy == TsPolicy::Tardis {
                        cur.u64("tsu wts")?
                    } else {
                        0
                    };
                    Some(Entry { tag, memts, wts })
                }
                f => return Err(format!("tsu slot flag must be 0 or 1, got {f}")),
            };
        }
        self.floor_ts = cur.u64("tsu floor_ts")?;
        self.lookups = cur.u64("tsu lookups")?;
        self.inserts = cur.u64("tsu inserts")?;
        self.evictions = cur.u64("tsu evictions")?;
        self.ts_rollovers = cur.u64("tsu ts_rollovers")?;
        self.max_memts = cur.u64("tsu max_memts")?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_read_gets_fresh_lease() {
        let mut t = Tsu::new(1024, Leases::default());
        let ts = t.on_read(0x40, 0);
        // memts starts at 0: Mrts = 0 + RdLease, Mwts = Mrts - RdLease.
        assert_eq!(ts, TsPair { rts: 10, wts: 0 });
    }

    #[test]
    fn repeated_reads_extend_lease_monotonically() {
        let mut t = Tsu::new(1024, Leases::default());
        let a = t.on_read(0x40, 0);
        let b = t.on_read(0x40, 0);
        let c = t.on_read(0x40, 0);
        assert_eq!((a.rts, b.rts, c.rts), (10, 20, 30));
        // Each wts is the previous memts.
        assert_eq!((b.wts, c.wts), (10, 20));
    }

    #[test]
    fn writes_use_wr_lease() {
        let mut t = Tsu::new(1024, Leases { rd: 10, wr: 5 });
        let r = t.on_read(0x80, 0); // memts: 0 -> 10
        let w = t.on_write(0x80, 0); // memts: 10 -> 15
        assert_eq!(r, TsPair { rts: 10, wts: 0 });
        assert_eq!(w, TsPair { rts: 15, wts: 10 });
        // A write's visibility time (wts) is after the earlier read lease
        // began, ordering the write after those reads in logical time.
        assert!(w.wts >= r.wts);
    }

    #[test]
    fn distinct_blocks_are_independent() {
        let mut t = Tsu::new(1024, Leases::default());
        t.on_read(0x40, 0);
        t.on_read(0x40, 0);
        let fresh = t.on_read(0x4000, 0);
        assert_eq!(fresh, TsPair { rts: 10, wts: 0 });
    }

    #[test]
    fn eviction_keeps_monotonic_floor() {
        // Tiny TSU: 8 entries = 1 set of 8 ways; 9 distinct same-set blocks.
        let mut t = Tsu::new(8, Leases::default());
        // sets = 1 so every line lands in the same set.
        let mut last = TsPair::default();
        for i in 0..9u64 {
            last = t.on_read(i * 64, 0);
        }
        assert_eq!(t.evictions, 1);
        // 9th allocation evicted the lowest-memts entry (memts=10); the new
        // entry starts at floor >= 10, not 0.
        assert!(last.wts >= 10, "fresh entry must start above evicted memts, got {last:?}");
        // Re-reading the evicted block also starts above the floor.
        let again = t.on_read(0, 0);
        assert!(again.wts >= 10);
    }

    #[test]
    fn max_memts_tracks_high_water_mark() {
        let mut t = Tsu::new(1024, Leases::default());
        t.on_read(0, 0);
        t.on_write(64, 0);
        t.on_read(0, 0);
        assert_eq!(t.max_memts, 20);
    }

    #[test]
    fn finite_width_counts_epoch_rollovers() {
        let mut t = Tsu::new(1024, Leases::default());
        t.set_ts_bits(4); // epoch span 16, rd lease 10
        t.on_read(0, 0); // memts 10, epoch 0
        assert_eq!(t.ts_rollovers, 0);
        t.on_read(0, 0); // memts 20, epoch 1
        assert_eq!(t.ts_rollovers, 1);
        for _ in 0..8 {
            t.on_read(0, 0); // memts 100, epoch 6
        }
        assert_eq!(t.ts_rollovers, 6);
        // Unbounded counters never roll over.
        let mut u = Tsu::new(1024, Leases::default());
        for _ in 0..100 {
            u.on_read(0, 0);
        }
        assert_eq!(u.ts_rollovers, 0);
    }

    #[test]
    fn tardis_reads_renew_the_lease_without_moving_wts() {
        let mut t = Tsu::new(1024, Leases::default()).with_policy(TsPolicy::Tardis);
        let a = t.on_read(0x40, 0);
        let b = t.on_read(0x40, 0);
        let c = t.on_read(0x40, 0);
        // The read frontier extends; the version timestamp is stable.
        assert_eq!((a.rts, b.rts, c.rts), (10, 20, 30));
        assert_eq!((a.wts, b.wts, c.wts), (0, 0, 0));
    }

    #[test]
    fn tardis_write_bumps_wts_past_the_read_frontier() {
        let mut t = Tsu::new(1024, Leases { rd: 10, wr: 5 }).with_policy(TsPolicy::Tardis);
        t.on_read(0x40, 0); // frontier 10
        let w = t.on_write(0x40, 0);
        // No outstanding lease (rts <= 10) can cover the new version.
        assert_eq!(w, TsPair { rts: 16, wts: 11 });
        let r = t.on_read(0x40, 0);
        assert_eq!(r, TsPair { rts: 26, wts: 11 });
    }

    #[test]
    fn hlc_floors_grants_by_coarse_physical_time() {
        let mut t = Tsu::new(1024, Leases::default()).with_policy(TsPolicy::Hlc);
        let early = t.on_read(0x40, 0);
        assert_eq!(early, TsPair { rts: 10, wts: 0 });
        // At cycle 4096 (phys 16 with HLC_SHIFT=8) the hybrid clock has
        // overtaken the lease chain: the grant base jumps to phys.
        let late = t.on_read(0x40, 4096);
        assert_eq!(late.wts, 4096 >> tsproto::HLC_SHIFT);
        assert_eq!(late.rts, late.wts + 10);
        // Misses are floored too.
        let miss = t.on_read(0x8000, 4096);
        assert_eq!(miss.wts, 4096 >> tsproto::HLC_SHIFT);
    }

    #[test]
    fn tardis_state_roundtrips_with_per_entry_wts() {
        let mut t = Tsu::new(1024, Leases::default()).with_policy(TsPolicy::Tardis);
        t.on_read(0x40, 0);
        t.on_write(0x40, 0);
        t.on_read(0x80, 0);
        let mut bytes = Vec::new();
        t.save_state(&mut bytes);
        let mut fresh = Tsu::new(1024, Leases::default()).with_policy(TsPolicy::Tardis);
        let mut cur = crate::snapshot::format::Cur::new(&bytes);
        fresh.load_state(&mut cur).unwrap();
        // The restored TSU answers exactly like the original would.
        assert_eq!(fresh.on_read(0x40, 0), t.on_read(0x40, 0));
        assert_eq!(fresh.on_write(0x80, 0), t.on_write(0x80, 0));
    }
}
