//! Physical address mapping.
//!
//! Two topologies (paper §4.1):
//!
//! * **SharedMem (MGPU-SM)** — one flat physical address space interleaved
//!   across all HBM stacks at 4 KB page granularity ("we allocate memory by
//!   interleaving 4 KB pages across all the memory modules").
//! * **Rdma** — each GPU owns a contiguous partition of the address space,
//!   itself page-interleaved across that GPU's local stacks; accesses to a
//!   remote partition cross the PCIe switch.
//!
//! Within a GPU, cache lines are interleaved across the L2 banks.

/// Which MGPU topology the address map describes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Topology {
    SharedMem,
    Rdma,
}

/// Address decomposition rules for one MGPU system instance.
#[derive(Clone, Debug)]
pub struct AddrMap {
    pub topology: Topology,
    pub n_gpus: u32,
    /// HBM stacks per GPU (SharedMem: stacks are global = n_gpus * this
    /// only when `shared_stacks` is false; the paper's example uses a fixed
    /// shared pool, see `total_stacks`).
    pub stacks_per_gpu: u32,
    /// L2 banks per GPU.
    pub l2_banks: u32,
    /// Bytes per GPU partition (Rdma) — also sizes the flat space.
    pub gpu_mem_bytes: u64,
    /// Page interleave granularity.
    pub page: u64,
    /// Cache line size.
    pub line: u64,
}

impl AddrMap {
    pub fn new(
        topology: Topology,
        n_gpus: u32,
        stacks_per_gpu: u32,
        l2_banks: u32,
        gpu_mem_bytes: u64,
    ) -> Self {
        AddrMap {
            topology,
            n_gpus,
            stacks_per_gpu,
            l2_banks,
            gpu_mem_bytes,
            page: 4096,
            line: super::LINE,
        }
    }

    /// Total number of memory controllers / HBM stacks in the system.
    pub fn total_stacks(&self) -> u32 {
        self.n_gpus * self.stacks_per_gpu
    }

    /// Total addressable bytes.
    pub fn total_bytes(&self) -> u64 {
        self.gpu_mem_bytes * self.n_gpus as u64
    }

    /// Align an address down to its line base.
    pub fn line_base(&self, addr: u64) -> u64 {
        addr & !(self.line - 1)
    }

    /// The GPU owning `addr`'s partition (Rdma home / HMG home node).
    /// In SharedMem the notion still exists for data-placement decisions
    /// but carries no NUMA cost.
    pub fn home_gpu(&self, addr: u64) -> u32 {
        ((addr / self.gpu_mem_bytes) as u32).min(self.n_gpus - 1)
    }

    /// Global index of the HBM stack (= memory controller) serving `addr`.
    pub fn stack_of(&self, addr: u64) -> u32 {
        match self.topology {
            Topology::SharedMem => {
                // Flat space: pages interleave across ALL stacks.
                ((addr / self.page) % self.total_stacks() as u64) as u32
            }
            Topology::Rdma => {
                // Partitioned: pages interleave across the owner's stacks.
                let gpu = self.home_gpu(addr);
                let local = (addr % self.gpu_mem_bytes) / self.page;
                gpu * self.stacks_per_gpu + (local % self.stacks_per_gpu as u64) as u32
            }
        }
    }

    /// The GPU that physically hosts HBM stack `stack` (stacks are
    /// numbered `gpu * stacks_per_gpu + local`). This is the ownership
    /// relation the partitioned fabric uses to place each MC/TSU in its
    /// owner GPU's engine shard.
    pub fn stack_owner(&self, stack: u32) -> u32 {
        debug_assert!(stack < self.total_stacks());
        stack / self.stacks_per_gpu
    }

    /// L2 bank index within a GPU for `addr` (line-interleaved).
    pub fn l2_bank_of(&self, addr: u64) -> u32 {
        ((addr / self.line) % self.l2_banks as u64) as u32
    }

    /// Whether `addr` is local to `gpu` (always true under SharedMem).
    pub fn is_local(&self, gpu: u32, addr: u64) -> bool {
        match self.topology {
            Topology::SharedMem => true,
            Topology::Rdma => self.home_gpu(addr) == gpu,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sm4() -> AddrMap {
        AddrMap::new(Topology::SharedMem, 4, 8, 8, 512 << 20)
    }

    fn rdma4() -> AddrMap {
        AddrMap::new(Topology::Rdma, 4, 8, 8, 512 << 20)
    }

    #[test]
    fn shared_mem_interleaves_pages_across_all_stacks() {
        let m = sm4();
        assert_eq!(m.total_stacks(), 32);
        let stacks: Vec<u32> = (0..64u64).map(|p| m.stack_of(p * 4096)).collect();
        // First 32 pages hit each stack exactly once, round-robin.
        assert_eq!(stacks[..32], (0..32).collect::<Vec<u32>>()[..]);
        assert_eq!(stacks[32], 0);
        // Within one page, same stack.
        assert_eq!(m.stack_of(5 * 4096 + 64), m.stack_of(5 * 4096));
    }

    #[test]
    fn rdma_partitions_by_gpu() {
        let m = rdma4();
        let part = 512u64 << 20;
        assert_eq!(m.home_gpu(0), 0);
        assert_eq!(m.home_gpu(part - 1), 0);
        assert_eq!(m.home_gpu(part), 1);
        assert_eq!(m.home_gpu(3 * part + 7), 3);
        assert!(m.is_local(1, part + 100));
        assert!(!m.is_local(0, part + 100));
        // Stacks stay inside the owner's range [gpu*8, gpu*8+8).
        for p in 0..32u64 {
            let s = m.stack_of(2 * part + p * 4096);
            assert!((16..24).contains(&s), "stack {s} outside gpu2");
        }
    }

    #[test]
    fn l2_banks_line_interleave() {
        let m = sm4();
        let banks: Vec<u32> = (0..16u64).map(|l| m.l2_bank_of(l * 64)).collect();
        assert_eq!(banks[..8], (0..8).collect::<Vec<u32>>()[..]);
        assert_eq!(banks[8], 0);
        // Sub-line offsets do not change the bank.
        assert_eq!(m.l2_bank_of(64 + 60), m.l2_bank_of(64));
    }

    #[test]
    fn line_base_masks_offset() {
        let m = sm4();
        assert_eq!(m.line_base(0), 0);
        assert_eq!(m.line_base(63), 0);
        assert_eq!(m.line_base(64), 64);
        assert_eq!(m.line_base(130), 128);
    }

    #[test]
    fn shared_mem_is_always_local() {
        let m = sm4();
        for gpu in 0..4 {
            assert!(m.is_local(gpu, 3 * (512 << 20) + 5));
        }
    }
}
