//! Set-associative cache array with true-LRU replacement and per-line
//! protocol metadata.
//!
//! The array is *storage only*: controllers (coherence/*.rs) implement the
//! protocol FSMs on top. Lines carry real data bytes so the simulator is
//! functionally correct, not just timing-correct — the final memory image
//! is checked against the XLA golden model (DESIGN.md S19).
//!
//! Layout: a tag/metadata array (`slots`) over **one flat byte backing**
//! (`data`, `sets * ways * line` bytes). The per-line `Box<[u8]>` of the
//! original layout cost an allocation per fill and scattered line bytes
//! across the heap; the flat backing allocates once at construction and
//! keeps a set's lines contiguous (§Perf log). Accessors hand out
//! [`LineRef`]/[`LineView`] views that pair a slot's metadata with its
//! slice of the backing.

use crate::mem::linebuf::LineBuf;
use crate::mem::LINE;

/// Geometry of a cache array.
#[derive(Clone, Copy, Debug)]
pub struct CacheParams {
    pub size_bytes: u64,
    pub ways: u32,
    pub line: u64,
}

impl CacheParams {
    pub fn new(size_bytes: u64, ways: u32) -> Self {
        CacheParams { size_bytes, ways, line: LINE }
    }

    pub fn sets(&self) -> u64 {
        self.size_bytes / (self.line * self.ways as u64)
    }
}

/// Tag + metadata of one resident line (data lives in the flat backing).
#[derive(Clone, Debug)]
struct Slot<M> {
    tag: u64,
    /// LRU stamp: larger = more recently used.
    lru: u64,
    dirty: bool,
    meta: M,
}

/// Mutable view of a resident line: slot metadata + its backing slice.
pub struct LineRef<'a, M> {
    pub dirty: &'a mut bool,
    pub meta: &'a mut M,
    pub data: &'a mut [u8],
}

/// Shared view of a resident line.
pub struct LineView<'a, M> {
    pub dirty: bool,
    pub meta: &'a M,
    pub data: &'a [u8],
}

/// Why `insert` displaced a line (metrics: capacity/conflict vs
/// coherency). Carries the victim's bytes inline — no allocation.
#[derive(Clone, Debug)]
pub struct Eviction<M> {
    pub addr: u64,
    pub dirty: bool,
    pub data: LineBuf,
    pub meta: M,
}

/// Set-associative cache storage.
#[derive(Clone, Debug)]
pub struct CacheArray<M> {
    params: CacheParams,
    sets: u64,
    /// `sets * ways` slots, row-major by set.
    slots: Vec<Option<Slot<M>>>,
    /// Flat data backing: slot `i` owns bytes `[i*line, (i+1)*line)`.
    data: Vec<u8>,
    /// Global LRU counter.
    clock: u64,
    /// Accesses that hit (metrics).
    pub hits: u64,
    /// Accesses that missed (metrics).
    pub misses: u64,
}

impl<M> CacheArray<M> {
    pub fn new(params: CacheParams) -> Self {
        let sets = params.sets();
        assert!(sets > 0, "cache too small for geometry: {params:?}");
        assert!(sets.is_power_of_two(), "set count must be a power of two, got {sets}");
        let n_slots = (sets * params.ways as u64) as usize;
        let mut slots = Vec::new();
        slots.resize_with(n_slots, || None);
        CacheArray {
            params,
            sets,
            slots,
            data: vec![0u8; n_slots * params.line as usize],
            clock: 0,
            hits: 0,
            misses: 0,
        }
    }

    pub fn params(&self) -> &CacheParams {
        &self.params
    }

    fn set_of(&self, addr: u64) -> u64 {
        (addr / self.params.line) & (self.sets - 1)
    }

    fn tag_of(&self, addr: u64) -> u64 {
        addr / self.params.line / self.sets
    }

    fn set_range(&self, set: u64) -> std::ops::Range<usize> {
        let start = (set * self.params.ways as u64) as usize;
        start..start + self.params.ways as usize
    }

    /// Reconstruct the line-aligned address of a resident line.
    fn addr_of(&self, set: u64, tag: u64) -> u64 {
        (tag * self.sets + set) * self.params.line
    }

    /// Byte range of slot `i` in the flat backing.
    #[inline]
    fn data_range(&self, i: usize) -> std::ops::Range<usize> {
        let line = self.params.line as usize;
        i * line..(i + 1) * line
    }

    /// Slot index of `addr` within its set, if resident.
    #[inline]
    fn index_of(&self, addr: u64) -> Option<usize> {
        let (set, tag) = (self.set_of(addr), self.tag_of(addr));
        self.set_range(set)
            .find(|&i| self.slots[i].as_ref().is_some_and(|l| l.tag == tag))
    }

    /// Look up `addr`; on hit, touch LRU and return the line. (Misses no
    /// longer advance the LRU clock — only touches stamp lines, and
    /// victim choice depends only on the stamps' relative order.)
    pub fn lookup(&mut self, addr: u64) -> Option<LineRef<'_, M>> {
        let idx = self.index_of(addr)?;
        self.clock += 1;
        let range = self.data_range(idx);
        let slot = self.slots[idx].as_mut().unwrap();
        slot.lru = self.clock;
        Some(LineRef {
            dirty: &mut slot.dirty,
            meta: &mut slot.meta,
            data: &mut self.data[range],
        })
    }

    /// Look up without touching LRU or counters (controller peeks).
    pub fn peek(&self, addr: u64) -> Option<LineView<'_, M>> {
        let idx = self.index_of(addr)?;
        let slot = self.slots[idx].as_ref().unwrap();
        Some(LineView {
            dirty: slot.dirty,
            meta: &slot.meta,
            data: &self.data[self.data_range(idx)],
        })
    }

    /// Record a hit/miss for metrics (controllers decide what counts:
    /// a tag hit with an expired lease is a *coherency* miss, not a hit).
    pub fn record(&mut self, hit: bool) {
        if hit {
            self.hits += 1;
        } else {
            self.misses += 1;
        }
    }

    /// Insert a line for `addr` (copying `data` into the flat backing),
    /// evicting the set's LRU victim if full. Returns the eviction (with
    /// its line-aligned address) if one occurred.
    pub fn insert(&mut self, addr: u64, data: &[u8], dirty: bool, meta: M) -> Option<Eviction<M>> {
        debug_assert_eq!(addr % self.params.line, 0, "insert wants line-aligned addr");
        debug_assert_eq!(data.len() as u64, self.params.line);
        let (set, tag) = (self.set_of(addr), self.tag_of(addr));
        self.clock += 1;
        let clock = self.clock;

        // One scan resolves same-tag refill, first free slot and LRU
        // victim together.
        let mut free: Option<usize> = None;
        let mut victim: Option<usize> = None;
        let mut same: Option<usize> = None;
        for i in self.set_range(set) {
            match &self.slots[i] {
                None => {
                    if free.is_none() {
                        free = Some(i);
                    }
                }
                Some(l) if l.tag == tag => {
                    same = Some(i);
                    break;
                }
                Some(l) => {
                    if victim.is_none_or(|v| l.lru < self.slots[v].as_ref().unwrap().lru) {
                        victim = Some(i);
                    }
                }
            }
        }

        if let Some(i) = same {
            // Refill of an existing line, in place.
            let range = self.data_range(i);
            let slot = self.slots[i].as_mut().unwrap();
            slot.dirty = dirty;
            slot.meta = meta;
            slot.lru = clock;
            self.data[range].copy_from_slice(data);
            return None;
        }

        if let Some(i) = free {
            self.slots[i] = Some(Slot { tag, lru: clock, dirty, meta });
            let range = self.data_range(i);
            self.data[range].copy_from_slice(data);
            return None;
        }

        let vi = victim.expect("a full set must yield a victim");
        let old = self.slots[vi].take().unwrap();
        let range = self.data_range(vi);
        let ev = Eviction {
            addr: self.addr_of(set, old.tag),
            dirty: old.dirty,
            data: LineBuf::from_slice(&self.data[range.clone()]),
            meta: old.meta,
        };
        self.slots[vi] = Some(Slot { tag, lru: clock, dirty, meta });
        self.data[range].copy_from_slice(data);
        Some(ev)
    }

    /// Would inserting `addr` evict a line? Returns the victim's
    /// (line-aligned address, dirty) without modifying anything. Used by
    /// write-back controllers that must drain the victim *before* the fill
    /// (paper §5.1: "first, the L2 performs a write to MM ... only then the
    /// L2 can service the pending read or write transactions").
    pub fn would_evict(&self, addr: u64) -> Option<(u64, bool)> {
        let (set, tag) = (self.set_of(addr), self.tag_of(addr));
        let mut best: Option<(u64, u64, bool)> = None; // (lru, addr, dirty)
        for i in self.set_range(set) {
            match &self.slots[i] {
                None => return None,                    // free slot: no eviction
                Some(l) if l.tag == tag => return None, // in-place refill
                Some(l) => {
                    if best.is_none_or(|(lru, _, _)| l.lru < lru) {
                        best = Some((l.lru, self.addr_of(set, l.tag), l.dirty));
                    }
                }
            }
        }
        best.map(|(_, a, d)| (a, d))
    }

    /// Single-scan replacement for the `would_evict` + `invalidate` pair:
    /// if inserting `addr` would evict a *dirty* victim, remove and return
    /// it. Clean victims stay resident until the actual `insert` — the
    /// same timing contract the two-call sequence implemented, without
    /// scanning the set twice.
    pub fn take_dirty_victim(&mut self, addr: u64) -> Option<Eviction<M>> {
        let (set, tag) = (self.set_of(addr), self.tag_of(addr));
        let mut victim: Option<usize> = None;
        for i in self.set_range(set) {
            match &self.slots[i] {
                None => return None,
                Some(l) if l.tag == tag => return None,
                Some(l) => {
                    if victim.is_none_or(|v| l.lru < self.slots[v].as_ref().unwrap().lru) {
                        victim = Some(i);
                    }
                }
            }
        }
        let vi = victim?;
        if !self.slots[vi].as_ref().unwrap().dirty {
            return None;
        }
        let old = self.slots[vi].take().unwrap();
        Some(Eviction {
            addr: self.addr_of(set, old.tag),
            dirty: true,
            data: LineBuf::from_slice(&self.data[self.data_range(vi)]),
            meta: old.meta,
        })
    }

    /// Drop `addr`'s line if resident; returns it.
    pub fn invalidate(&mut self, addr: u64) -> Option<Eviction<M>> {
        let idx = self.index_of(addr)?;
        let set = idx as u64 / self.params.ways as u64;
        let line = self.slots[idx].take().unwrap();
        Some(Eviction {
            addr: self.addr_of(set, line.tag),
            dirty: line.dirty,
            data: LineBuf::from_slice(&self.data[self.data_range(idx)]),
            meta: line.meta,
        })
    }

    /// Drain every resident line (fence flushes); preserves nothing.
    pub fn drain(&mut self) -> Vec<Eviction<M>> {
        let mut out = Vec::new();
        for set in 0..self.sets {
            for i in self.set_range(set) {
                if let Some(line) = self.slots[i].take() {
                    out.push(Eviction {
                        addr: self.addr_of(set, line.tag),
                        dirty: line.dirty,
                        data: LineBuf::from_slice(&self.data[self.data_range(i)]),
                        meta: line.meta,
                    });
                }
            }
        }
        out
    }

    /// Drop every resident line without materializing evictions
    /// (write-through fences: all lines are clean by construction).
    pub fn clear(&mut self) {
        for s in &mut self.slots {
            *s = None;
        }
    }

    /// Visit every resident line (fence cts updates, WB scans).
    pub fn for_each_mut(&mut self, mut f: impl FnMut(u64, LineRef<'_, M>)) {
        let line = self.params.line as usize;
        for set in 0..self.sets {
            for i in self.set_range(set) {
                if let Some(slot) = self.slots[i].as_mut() {
                    let addr = (slot.tag * self.sets + set) * self.params.line;
                    f(
                        addr,
                        LineRef {
                            dirty: &mut slot.dirty,
                            meta: &mut slot.meta,
                            data: &mut self.data[i * line..(i + 1) * line],
                        },
                    );
                }
            }
        }
    }

    /// Number of resident lines.
    pub fn occupancy(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    /// Serialize the array's mutable state for a snapshot
    /// (docs/SNAPSHOT.md): LRU clock, hit/miss counters, every slot
    /// (tag, LRU stamp, dirty bit, protocol metadata via `put_meta`)
    /// and the flat data backing verbatim. Geometry is not written —
    /// it is rebuilt from the config and validated on load.
    pub fn save_with(&self, out: &mut Vec<u8>, put_meta: impl Fn(&M, &mut Vec<u8>)) {
        use crate::snapshot::format::put;
        put(out, self.clock);
        put(out, self.hits);
        put(out, self.misses);
        put(out, self.slots.len() as u64);
        for slot in &self.slots {
            match slot {
                None => out.push(0),
                Some(s) => {
                    out.push(1);
                    put(out, s.tag);
                    put(out, s.lru);
                    out.push(s.dirty as u8);
                    put_meta(&s.meta, out);
                }
            }
        }
        put(out, self.data.len() as u64);
        out.extend_from_slice(&self.data);
    }

    /// Restore the state written by [`CacheArray::save_with`] into an
    /// array of the same geometry.
    pub fn load_with(
        &mut self,
        cur: &mut crate::snapshot::format::Cur,
        read_meta: impl Fn(&mut crate::snapshot::format::Cur) -> Result<M, String>,
    ) -> Result<(), String> {
        self.clock = cur.u64("cache clock")?;
        self.hits = cur.u64("cache hits")?;
        self.misses = cur.u64("cache misses")?;
        let n = cur.u64("cache slot count")? as usize;
        if n != self.slots.len() {
            return Err(format!(
                "snapshot cache has {n} slots, this geometry has {} — the configurations \
                 differ",
                self.slots.len()
            ));
        }
        for i in 0..n {
            self.slots[i] = match cur.byte("cache slot flag")? {
                0 => None,
                1 => Some(Slot {
                    tag: cur.u64("cache slot tag")?,
                    lru: cur.u64("cache slot lru")?,
                    dirty: cur.bool("cache slot dirty")?,
                    meta: read_meta(cur)?,
                }),
                f => return Err(format!("cache slot flag must be 0 or 1, got {f}")),
            };
        }
        let len = cur.u64("cache data length")? as usize;
        if len != self.data.len() {
            return Err(format!(
                "snapshot cache backing is {len} bytes, this geometry has {} — the \
                 configurations differ",
                self.data.len()
            ));
        }
        self.data.copy_from_slice(cur.bytes(len, "cache data backing")?);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn arr(size: u64, ways: u32) -> CacheArray<u32> {
        CacheArray::new(CacheParams::new(size, ways))
    }

    fn line_data(fill: u8) -> [u8; 64] {
        [fill; 64]
    }

    #[test]
    fn geometry_16kb_4way() {
        // Paper Table 2: L1 vector cache 16 KB 4-way, 64 B lines -> 64 sets.
        let a = arr(16 << 10, 4);
        assert_eq!(a.params().sets(), 64);
    }

    #[test]
    fn hit_after_insert() {
        let mut a = arr(4096, 4);
        assert!(a.lookup(0x40).is_none());
        a.insert(0x40, &line_data(7), false, 1);
        let line = a.lookup(0x40).expect("hit");
        assert_eq!(line.data[0], 7);
        assert_eq!(*line.meta, 1);
        // Different offset within the same line also hits via line_base
        // handled by controllers; the array expects aligned addrs for
        // insert but lookup masks internally through set/tag math.
        assert!(a.lookup(0x40 + 4).is_some());
    }

    #[test]
    fn lru_eviction_order() {
        // 1 set, 2 ways: 128-byte cache.
        let mut a = arr(128, 2);
        a.insert(0, &line_data(1), false, 0);
        a.insert(64, &line_data(2), false, 0);
        a.lookup(0); // touch line 0 -> line 64 becomes LRU
        let ev = a.insert(128, &line_data(3), true, 0).expect("eviction");
        assert_eq!(ev.addr, 64);
        assert_eq!(ev.data[0], 2);
        assert!(a.peek(0).is_some());
        assert!(a.peek(64).is_none());
        assert!(a.peek(128).is_some());
    }

    #[test]
    fn conflict_misses_within_one_set() {
        // 4 sets x 1 way; lines 0, 256 (4 sets * 64) collide in set 0.
        let mut a = arr(256, 1);
        a.insert(0, &line_data(1), false, 0);
        let ev = a.insert(256, &line_data(2), false, 0).expect("conflict eviction");
        assert_eq!(ev.addr, 0);
    }

    #[test]
    fn same_tag_insert_replaces_in_place() {
        let mut a = arr(4096, 4);
        a.insert(0x80, &line_data(1), false, 9);
        assert!(a.insert(0x80, &line_data(2), true, 10).is_none());
        let l = a.peek(0x80).unwrap();
        assert_eq!(l.data[0], 2);
        assert!(l.dirty);
        assert_eq!(*l.meta, 10);
        assert_eq!(a.occupancy(), 1);
    }

    #[test]
    fn invalidate_removes() {
        let mut a = arr(4096, 4);
        a.insert(0x100, &line_data(5), true, 0);
        let ev = a.invalidate(0x100).expect("was resident");
        assert!(ev.dirty);
        assert_eq!(ev.addr, 0x100);
        assert_eq!(ev.data[0], 5);
        assert!(a.peek(0x100).is_none());
        assert!(a.invalidate(0x100).is_none());
    }

    #[test]
    fn drain_returns_everything_with_addresses() {
        let mut a = arr(1024, 2);
        for i in 0..8u64 {
            a.insert(i * 64, &line_data(i as u8), i % 2 == 0, 0);
        }
        let mut drained = a.drain();
        drained.sort_by_key(|e| e.addr);
        assert_eq!(drained.len(), 8);
        for (i, e) in drained.iter().enumerate() {
            assert_eq!(e.addr, i as u64 * 64);
            assert_eq!(e.data[0], i as u8);
            assert_eq!(e.data.len(), 64);
        }
        assert_eq!(a.occupancy(), 0);
    }

    #[test]
    fn clear_drops_everything() {
        let mut a = arr(1024, 2);
        for i in 0..8u64 {
            a.insert(i * 64, &line_data(1), false, 0);
        }
        a.clear();
        assert_eq!(a.occupancy(), 0);
        assert!(a.peek(0).is_none());
    }

    #[test]
    fn addr_reconstruction_roundtrip() {
        let mut a = arr(16 << 10, 4);
        // Large tags: address beyond 1 GB.
        let addr = (1u64 << 30) + 0x1fc0;
        a.insert(addr, &line_data(3), true, 0);
        let mut seen = None;
        a.for_each_mut(|la, l| {
            assert!(*l.dirty);
            assert_eq!(l.data[0], 3);
            seen = Some(la);
        });
        assert_eq!(seen, Some(addr));
    }

    #[test]
    fn take_dirty_victim_matches_would_evict() {
        // 1 set, 2 ways; fill with one clean and one dirty line.
        let mut a = arr(128, 2);
        a.insert(0, &line_data(1), true, 0); // dirty, LRU after next touch
        a.insert(64, &line_data(2), false, 0);
        a.lookup(64); // line 0 is now the LRU victim
        assert_eq!(a.would_evict(128), Some((0, true)));
        let ev = a.take_dirty_victim(128).expect("dirty victim");
        assert_eq!((ev.addr, ev.dirty, ev.data[0]), (0, true, 1));
        // Victim removed: next insert fills the free slot, no eviction.
        assert!(a.insert(128, &line_data(3), false, 0).is_none());
        assert_eq!(a.occupancy(), 2);
    }

    #[test]
    fn take_dirty_victim_leaves_clean_victims_resident() {
        let mut a = arr(128, 2);
        a.insert(0, &line_data(1), false, 0);
        a.insert(64, &line_data(2), true, 0);
        a.lookup(64); // clean line 0 is the LRU victim
        assert_eq!(a.would_evict(128), Some((0, false)));
        assert!(a.take_dirty_victim(128).is_none());
        assert_eq!(a.occupancy(), 2, "clean victim must stay until insert");
        // A same-tag or free-slot situation also returns None.
        assert!(a.take_dirty_victim(0).is_none());
    }

    #[test]
    fn lru_untouched_by_misses() {
        // A miss between two touches must not perturb victim choice.
        let mut a = arr(128, 2);
        a.insert(0, &line_data(1), false, 0);
        a.insert(64, &line_data(2), false, 0);
        a.lookup(0);
        for _ in 0..10 {
            assert!(a.lookup(0x4000).is_none()); // misses
        }
        let ev = a.insert(128, &line_data(3), false, 0).unwrap();
        assert_eq!(ev.addr, 64);
    }

    #[test]
    fn flat_backing_keeps_lines_separate() {
        let mut a = arr(4096, 4);
        a.insert(0x00, &line_data(0xAA), false, 0);
        a.insert(0x40, &line_data(0xBB), false, 0);
        {
            let l = a.lookup(0x00).unwrap();
            l.data[3] = 0x11;
        }
        assert_eq!(a.peek(0x00).unwrap().data[3], 0x11);
        assert!(a.peek(0x40).unwrap().data.iter().all(|&b| b == 0xBB));
    }
}
