//! Set-associative cache array with true-LRU replacement and per-line
//! protocol metadata.
//!
//! The array is *storage only*: controllers (coherence/*.rs) implement the
//! protocol FSMs on top. Lines carry real data bytes so the simulator is
//! functionally correct, not just timing-correct — the final memory image
//! is checked against the XLA golden model (DESIGN.md S19).

use crate::mem::LINE;

/// Geometry of a cache array.
#[derive(Clone, Copy, Debug)]
pub struct CacheParams {
    pub size_bytes: u64,
    pub ways: u32,
    pub line: u64,
}

impl CacheParams {
    pub fn new(size_bytes: u64, ways: u32) -> Self {
        CacheParams { size_bytes, ways, line: LINE }
    }

    pub fn sets(&self) -> u64 {
        self.size_bytes / (self.line * self.ways as u64)
    }
}

/// One resident cache line.
#[derive(Clone, Debug)]
pub struct Line<M> {
    pub tag: u64,
    pub dirty: bool,
    /// LRU stamp: larger = more recently used.
    lru: u64,
    pub data: Box<[u8]>,
    pub meta: M,
}

/// Why `insert` displaced a line (metrics: capacity/conflict vs coherency).
#[derive(Clone, Debug)]
pub struct Eviction<M> {
    pub addr: u64,
    pub dirty: bool,
    pub data: Box<[u8]>,
    pub meta: M,
}

/// Set-associative cache storage.
#[derive(Clone, Debug)]
pub struct CacheArray<M> {
    params: CacheParams,
    sets: u64,
    /// `sets * ways` slots, row-major by set.
    slots: Vec<Option<Line<M>>>,
    /// Global LRU counter.
    clock: u64,
    /// Accesses that hit (metrics).
    pub hits: u64,
    /// Accesses that missed (metrics).
    pub misses: u64,
}

impl<M> CacheArray<M> {
    pub fn new(params: CacheParams) -> Self {
        let sets = params.sets();
        assert!(sets > 0, "cache too small for geometry: {params:?}");
        assert!(sets.is_power_of_two(), "set count must be a power of two, got {sets}");
        let mut slots = Vec::new();
        slots.resize_with((sets * params.ways as u64) as usize, || None);
        CacheArray { params, sets, slots, clock: 0, hits: 0, misses: 0 }
    }

    pub fn params(&self) -> &CacheParams {
        &self.params
    }

    fn set_of(&self, addr: u64) -> u64 {
        (addr / self.params.line) & (self.sets - 1)
    }

    fn tag_of(&self, addr: u64) -> u64 {
        addr / self.params.line / self.sets
    }

    fn set_range(&self, set: u64) -> std::ops::Range<usize> {
        let start = (set * self.params.ways as u64) as usize;
        start..start + self.params.ways as usize
    }

    /// Reconstruct the line-aligned address of a resident line.
    fn addr_of(&self, set: u64, tag: u64) -> u64 {
        (tag * self.sets + set) * self.params.line
    }

    /// Look up `addr`; on hit, touch LRU and return the line.
    pub fn lookup(&mut self, addr: u64) -> Option<&mut Line<M>> {
        let (set, tag) = (self.set_of(addr), self.tag_of(addr));
        let range = self.set_range(set);
        self.clock += 1;
        let clock = self.clock;
        let slot = self.slots[range]
            .iter_mut()
            .find(|s| s.as_ref().is_some_and(|l| l.tag == tag))?;
        let line = slot.as_mut().unwrap();
        line.lru = clock;
        Some(line)
    }

    /// Look up without touching LRU or counters (controller peeks).
    pub fn peek(&self, addr: u64) -> Option<&Line<M>> {
        let (set, tag) = (self.set_of(addr), self.tag_of(addr));
        self.slots[self.set_range(set)]
            .iter()
            .flatten()
            .find(|l| l.tag == tag)
    }

    /// Record a hit/miss for metrics (controllers decide what counts:
    /// a tag hit with an expired lease is a *coherency* miss, not a hit).
    pub fn record(&mut self, hit: bool) {
        if hit {
            self.hits += 1;
        } else {
            self.misses += 1;
        }
    }

    /// Insert a line for `addr`, evicting the set's LRU victim if full.
    /// Returns the eviction (with its line-aligned address) if one occurred.
    pub fn insert(&mut self, addr: u64, data: Box<[u8]>, dirty: bool, meta: M) -> Option<Eviction<M>> {
        debug_assert_eq!(addr % self.params.line, 0, "insert wants line-aligned addr");
        debug_assert_eq!(data.len() as u64, self.params.line);
        let (set, tag) = (self.set_of(addr), self.tag_of(addr));
        self.clock += 1;
        let clock = self.clock;
        let range = self.set_range(set);

        // Same-tag replacement (refill of an existing line).
        if let Some(slot) = self.slots[range.clone()]
            .iter_mut()
            .find(|s| s.as_ref().is_some_and(|l| l.tag == tag))
        {
            let line = slot.as_mut().unwrap();
            line.data = data;
            line.dirty = dirty;
            line.meta = meta;
            line.lru = clock;
            return None;
        }

        // Free slot?
        if let Some(slot) = self.slots[range.clone()].iter_mut().find(|s| s.is_none()) {
            *slot = Some(Line { tag, dirty, lru: clock, data, meta });
            return None;
        }

        // Evict LRU.
        let victim_idx = range
            .clone()
            .min_by_key(|&i| self.slots[i].as_ref().unwrap().lru)
            .unwrap();
        let victim = self.slots[victim_idx].take().unwrap();
        self.slots[victim_idx] = Some(Line { tag, dirty, lru: clock, data, meta });
        Some(Eviction {
            addr: self.addr_of(set, victim.tag),
            dirty: victim.dirty,
            data: victim.data,
            meta: victim.meta,
        })
    }

    /// Would inserting `addr` evict a line? Returns the victim's
    /// (line-aligned address, dirty) without modifying anything. Used by
    /// write-back controllers that must drain the victim *before* the fill
    /// (paper §5.1: "first, the L2 performs a write to MM ... only then the
    /// L2 can service the pending read or write transactions").
    pub fn would_evict(&self, addr: u64) -> Option<(u64, bool)> {
        let (set, tag) = (self.set_of(addr), self.tag_of(addr));
        let range = self.set_range(set);
        let mut lru_best: Option<(u64, u64, bool)> = None; // (lru, addr, dirty)
        for i in range {
            match &self.slots[i] {
                None => return None, // free slot: no eviction
                Some(l) if l.tag == tag => return None, // in-place refill
                Some(l) => {
                    let cand = (l.lru, self.addr_of(set, l.tag), l.dirty);
                    if lru_best.is_none_or(|(lru, _, _)| cand.0 < lru) {
                        lru_best = Some(cand);
                    }
                }
            }
        }
        lru_best.map(|(_, a, d)| (a, d))
    }

    /// Drop `addr`'s line if resident; returns it.
    pub fn invalidate(&mut self, addr: u64) -> Option<Eviction<M>> {
        let (set, tag) = (self.set_of(addr), self.tag_of(addr));
        let range = self.set_range(set);
        let idx = range.filter(|&i| {
            self.slots[i].as_ref().is_some_and(|l| l.tag == tag)
        }).next()?;
        let line = self.slots[idx].take().unwrap();
        Some(Eviction { addr: self.addr_of(set, line.tag), dirty: line.dirty, data: line.data, meta: line.meta })
    }

    /// Drain every resident line (fence flushes); preserves nothing.
    pub fn drain(&mut self) -> Vec<Eviction<M>> {
        let mut out = Vec::new();
        for set in 0..self.sets {
            for i in self.set_range(set) {
                if let Some(line) = self.slots[i].take() {
                    out.push(Eviction {
                        addr: self.addr_of(set, line.tag),
                        dirty: line.dirty,
                        data: line.data,
                        meta: line.meta,
                    });
                }
            }
        }
        out
    }

    /// Visit every resident line (fence cts updates, WB scans).
    pub fn for_each_mut(&mut self, mut f: impl FnMut(u64, &mut Line<M>)) {
        for set in 0..self.sets {
            for i in self.set_range(set) {
                if let Some(line) = self.slots[i].as_mut() {
                    let addr = (line.tag * self.sets + set) * self.params.line;
                    f(addr, line);
                }
            }
        }
    }

    /// Number of resident lines.
    pub fn occupancy(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn arr(size: u64, ways: u32) -> CacheArray<u32> {
        CacheArray::new(CacheParams::new(size, ways))
    }

    fn line_data(fill: u8) -> Box<[u8]> {
        vec![fill; 64].into_boxed_slice()
    }

    #[test]
    fn geometry_16kb_4way() {
        // Paper Table 2: L1 vector cache 16 KB 4-way, 64 B lines -> 64 sets.
        let a = arr(16 << 10, 4);
        assert_eq!(a.params().sets(), 64);
    }

    #[test]
    fn hit_after_insert() {
        let mut a = arr(4096, 4);
        assert!(a.lookup(0x40).is_none());
        a.insert(0x40, line_data(7), false, 1);
        let line = a.lookup(0x40).expect("hit");
        assert_eq!(line.data[0], 7);
        assert_eq!(line.meta, 1);
        // Different offset within the same line also hits via line_base
        // handled by controllers; the array expects aligned addrs for
        // insert but lookup masks internally through set/tag math.
        assert!(a.lookup(0x40 + 4).is_some());
    }

    #[test]
    fn lru_eviction_order() {
        // 1 set, 2 ways: 128-byte cache.
        let mut a = arr(128, 2);
        a.insert(0, line_data(1), false, 0);
        a.insert(64, line_data(2), false, 0);
        a.lookup(0); // touch line 0 -> line 64 becomes LRU
        let ev = a.insert(128, line_data(3), true, 0).expect("eviction");
        assert_eq!(ev.addr, 64);
        assert!(a.peek(0).is_some());
        assert!(a.peek(64).is_none());
        assert!(a.peek(128).is_some());
    }

    #[test]
    fn conflict_misses_within_one_set() {
        // 4 sets x 1 way; lines 0, 256 (4 sets * 64) collide in set 0.
        let mut a = arr(256, 1);
        a.insert(0, line_data(1), false, 0);
        let ev = a.insert(256, line_data(2), false, 0).expect("conflict eviction");
        assert_eq!(ev.addr, 0);
    }

    #[test]
    fn same_tag_insert_replaces_in_place() {
        let mut a = arr(4096, 4);
        a.insert(0x80, line_data(1), false, 9);
        assert!(a.insert(0x80, line_data(2), true, 10).is_none());
        let l = a.peek(0x80).unwrap();
        assert_eq!(l.data[0], 2);
        assert!(l.dirty);
        assert_eq!(l.meta, 10);
        assert_eq!(a.occupancy(), 1);
    }

    #[test]
    fn invalidate_removes() {
        let mut a = arr(4096, 4);
        a.insert(0x100, line_data(5), true, 0);
        let ev = a.invalidate(0x100).expect("was resident");
        assert!(ev.dirty);
        assert_eq!(ev.addr, 0x100);
        assert!(a.peek(0x100).is_none());
        assert!(a.invalidate(0x100).is_none());
    }

    #[test]
    fn drain_returns_everything_with_addresses() {
        let mut a = arr(1024, 2);
        for i in 0..8u64 {
            a.insert(i * 64, line_data(i as u8), i % 2 == 0, 0);
        }
        let mut drained = a.drain();
        drained.sort_by_key(|e| e.addr);
        assert_eq!(drained.len(), 8);
        for (i, e) in drained.iter().enumerate() {
            assert_eq!(e.addr, i as u64 * 64);
            assert_eq!(e.data[0], i as u8);
        }
        assert_eq!(a.occupancy(), 0);
    }

    #[test]
    fn addr_reconstruction_roundtrip() {
        let mut a = arr(16 << 10, 4);
        // Large tags: address beyond 1 GB.
        let addr = (1u64 << 30) + 0x1fc0;
        a.insert(addr, line_data(3), true, 0);
        let mut seen = None;
        a.for_each_mut(|la, l| {
            assert!(l.dirty);
            seen = Some(la);
        });
        assert_eq!(seen, Some(addr));
    }
}
