//! Fixed-capacity inline byte buffer for message payloads.
//!
//! Every memory transaction payload in the system is at most one cache
//! line (64 B): word-granularity CU accesses carry `size <= LINE`, fills
//! and write-backs carry exactly `LINE`. [`LineBuf`] stores those bytes
//! inline — `Copy`, no heap — so recycling a pooled `Box<MemReq>` never
//! frees or reallocates payload storage (§Perf: the two `Vec<u8>`
//! allocations per memory transaction dominated the event hot loop).
//!
//! The type dereferences to `[u8]`, so slicing, indexing, `len()` and
//! `to_vec()` all work exactly as they did on the `Vec<u8>` it replaces.

use crate::mem::LINE;

/// Inline payload buffer: up to one cache line of bytes plus a length.
#[derive(Clone, Copy)]
pub struct LineBuf {
    len: u8,
    bytes: [u8; LINE as usize],
}

impl LineBuf {
    /// Maximum payload size (one cache line).
    pub const CAP: usize = LINE as usize;

    /// Zero-length buffer (read requests, write acks).
    pub const fn empty() -> Self {
        LineBuf { len: 0, bytes: [0; Self::CAP] }
    }

    /// `len` zero bytes. Panics if `len > CAP` (a wiring bug).
    pub fn zeroed(len: usize) -> Self {
        assert!(len <= Self::CAP, "LineBuf::zeroed({len}) exceeds capacity");
        LineBuf { len: len as u8, bytes: [0; Self::CAP] }
    }

    /// Copy `src` into a fresh buffer. Panics if it exceeds one line.
    pub fn from_slice(src: &[u8]) -> Self {
        assert!(src.len() <= Self::CAP, "LineBuf::from_slice: {} bytes", src.len());
        let mut bytes = [0u8; Self::CAP];
        bytes[..src.len()].copy_from_slice(src);
        LineBuf { len: src.len() as u8, bytes }
    }

    /// Append `src`; panics if the result exceeds one line.
    pub fn extend_from_slice(&mut self, src: &[u8]) {
        let start = self.len as usize;
        let end = start + src.len();
        assert!(end <= Self::CAP, "LineBuf::extend_from_slice overflows");
        self.bytes[start..end].copy_from_slice(src);
        self.len = end as u8;
    }

    /// Grow (zero/`fill`-extending) or shrink to `new_len`, like
    /// `Vec::resize`. Panics if `new_len > CAP`.
    pub fn resize(&mut self, new_len: usize, fill: u8) {
        assert!(new_len <= Self::CAP, "LineBuf::resize({new_len}) exceeds capacity");
        let old = self.len as usize;
        if new_len > old {
            self.bytes[old..new_len].fill(fill);
        }
        self.len = new_len as u8;
    }

}

impl Default for LineBuf {
    fn default() -> Self {
        Self::empty()
    }
}

impl std::ops::Deref for LineBuf {
    type Target = [u8];
    #[inline]
    fn deref(&self) -> &[u8] {
        &self.bytes[..self.len as usize]
    }
}

impl std::ops::DerefMut for LineBuf {
    #[inline]
    fn deref_mut(&mut self) -> &mut [u8] {
        &mut self.bytes[..self.len as usize]
    }
}

impl PartialEq for LineBuf {
    fn eq(&self, other: &Self) -> bool {
        self[..] == other[..]
    }
}
impl Eq for LineBuf {}

impl std::fmt::Debug for LineBuf {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        // Payload bytes are rarely interesting in event dumps; keep
        // panics readable.
        write!(f, "LineBuf[{}B]", self.len)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_has_no_bytes() {
        let b = LineBuf::empty();
        assert_eq!(b.len(), 0);
        assert!(b.is_empty());
        assert_eq!(&b[..], &[] as &[u8]);
    }

    #[test]
    fn from_slice_roundtrips() {
        let b = LineBuf::from_slice(&[1, 2, 3, 4]);
        assert_eq!(b.len(), 4);
        assert_eq!(&b[..], &[1, 2, 3, 4]);
        assert_eq!(b.to_vec(), vec![1, 2, 3, 4]);
    }

    #[test]
    fn full_line_fits() {
        let b = LineBuf::from_slice(&[7u8; LineBuf::CAP]);
        assert_eq!(b.len(), LineBuf::CAP);
        assert!(b.iter().all(|&x| x == 7));
    }

    #[test]
    fn extend_and_resize_match_vec_semantics() {
        let mut b = LineBuf::empty();
        b.extend_from_slice(&[1, 2]);
        b.extend_from_slice(&[3]);
        assert_eq!(&b[..], &[1, 2, 3]);
        b.resize(6, 0);
        assert_eq!(&b[..], &[1, 2, 3, 0, 0, 0]);
        b.resize(2, 0);
        assert_eq!(&b[..], &[1, 2]);
        // Regrowing after a shrink re-zeroes the exposed tail.
        b.resize(3, 9);
        assert_eq!(&b[..], &[1, 2, 9]);
    }

    #[test]
    fn deref_mut_allows_in_place_writes() {
        let mut b = LineBuf::zeroed(8);
        b[2..6].copy_from_slice(&[5, 6, 7, 8]);
        assert_eq!(&b[..], &[0, 0, 5, 6, 7, 8, 0, 0]);
    }

    #[test]
    #[should_panic(expected = "exceeds capacity")]
    fn oversized_zeroed_panics() {
        LineBuf::zeroed(LineBuf::CAP + 1);
    }

    #[test]
    fn equality_ignores_stale_tail_bytes() {
        let mut a = LineBuf::from_slice(&[1, 2, 3]);
        a.resize(2, 0);
        let b = LineBuf::from_slice(&[1, 2]);
        assert_eq!(a, b);
    }
}
