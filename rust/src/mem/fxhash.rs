//! Dependency-free FxHash-style hasher for `u64`-keyed maps.
//!
//! The simulator's hottest maps (backing-store lines, MSHR entries,
//! write-combining buffers) are all keyed by 64-bit addresses. The std
//! `HashMap` default (SipHash-1-3) showed up at ~5% of total runtime in
//! perf (see `gpu/cu.rs` §Perf note); the Firefox `FxHasher` multiply-
//! and-rotate mix is a single cycle per word and is plenty for
//! non-adversarial address keys. The offline registry carries no
//! `rustc-hash`/`fxhash` crate, so the mix is implemented inline.

use std::collections::{HashMap, HashSet};
use std::hash::{BuildHasherDefault, Hasher};

/// The FxHash multiplier (a scrambled golden-ratio constant).
const SEED: u64 = 0x51_7c_c1_b7_27_22_0a_95;

/// One-word multiply-rotate hasher (FxHash).
#[derive(Clone, Copy, Debug, Default)]
pub struct FxHasher {
    hash: u64,
}

impl FxHasher {
    #[inline]
    fn add(&mut self, word: u64) {
        self.hash = (self.hash.rotate_left(5) ^ word).wrapping_mul(SEED);
    }
}

impl Hasher for FxHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.hash
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        // Generic path (str keys etc.): fold 8-byte words, then the tail.
        let mut chunks = bytes.chunks_exact(8);
        for c in chunks.by_ref() {
            self.add(u64::from_le_bytes(c.try_into().unwrap()));
        }
        let rem = chunks.remainder();
        if !rem.is_empty() {
            let mut tail = [0u8; 8];
            tail[..rem.len()].copy_from_slice(rem);
            self.add(u64::from_le_bytes(tail));
        }
    }

    #[inline]
    fn write_u8(&mut self, n: u8) {
        self.add(n as u64);
    }

    #[inline]
    fn write_u32(&mut self, n: u32) {
        self.add(n as u64);
    }

    #[inline]
    fn write_u64(&mut self, n: u64) {
        self.add(n);
    }

    #[inline]
    fn write_usize(&mut self, n: usize) {
        self.add(n as u64);
    }
}

/// Drop-in `HashMap`/`HashSet` aliases with the Fx hasher. Construct with
/// `FxHashMap::default()` (custom-hasher maps have no `new`).
pub type FxBuildHasher = BuildHasherDefault<FxHasher>;
pub type FxHashMap<K, V> = HashMap<K, V, FxBuildHasher>;
pub type FxHashSet<K> = HashSet<K, FxBuildHasher>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_roundtrips_u64_keys() {
        let mut m: FxHashMap<u64, u32> = FxHashMap::default();
        for i in 0..1000u64 {
            m.insert(i * 64, i as u32);
        }
        assert_eq!(m.len(), 1000);
        for i in 0..1000u64 {
            assert_eq!(m.get(&(i * 64)), Some(&(i as u32)));
        }
        assert_eq!(m.remove(&(5 * 64)), Some(5));
        assert_eq!(m.get(&(5 * 64)), None);
    }

    #[test]
    fn line_aligned_keys_spread() {
        // Cache-line-aligned addresses (low 6 bits zero) must not collapse
        // onto a few hash values — the exact failure mode of identity
        // hashing that motivates the multiply.
        let mut lows = FxHashSet::default();
        for i in 0..256u64 {
            let mut h = FxHasher::default();
            h.write_u64(i * 64);
            lows.insert(h.finish() & 0xff);
        }
        assert!(lows.len() > 100, "only {} distinct low bytes", lows.len());
    }

    #[test]
    fn generic_write_consumes_tails() {
        let mut a = FxHasher::default();
        a.write(b"hello world");
        let mut b = FxHasher::default();
        b.write(b"hello worle");
        assert_ne!(a.finish(), b.finish());
    }
}
