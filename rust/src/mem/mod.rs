//! Memory-system building blocks shared by every cache level and protocol:
//! address mapping (page interleave across HBM stacks, bank interleave
//! across L2 banks, RDMA partitioning), the set-associative cache array,
//! and the miss-status-holding-register (MSHR) file.

pub mod addr;
pub mod cache;
pub mod mshr;

pub use addr::AddrMap;
pub use cache::{CacheArray, CacheParams, Line};
pub use mshr::{Mshr, MshrEntry};

/// Cache line size in bytes (paper §3.2.6 assumes 64 B blocks).
pub const LINE: u64 = 64;
