//! Memory-system building blocks shared by every cache level and protocol:
//! address mapping (page interleave across HBM stacks, bank interleave
//! across L2 banks, RDMA partitioning), the set-associative cache array
//! (tag/metadata array over one flat data backing), the
//! miss-status-holding-register (MSHR) file, the inline line-payload
//! buffer ([`LineBuf`]) and the dependency-free [`fxhash`] hasher used by
//! every address-keyed map on the hot path.

pub mod addr;
pub mod cache;
pub mod fxhash;
pub mod linebuf;
pub mod mshr;

pub use addr::AddrMap;
pub use cache::{CacheArray, CacheParams, Eviction, LineRef, LineView};
pub use fxhash::{FxHashMap, FxHashSet};
pub use linebuf::LineBuf;
pub use mshr::{Mshr, MshrEntry};

/// Cache line size in bytes (paper §3.2.6 assumes 64 B blocks).
pub const LINE: u64 = 64;
