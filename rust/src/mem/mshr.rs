//! Miss-Status Holding Registers.
//!
//! An MSHR entry exists for every line with an outstanding fill or a
//! locked write (HALCONE locks a block from the write hit until the
//! lower level's timestamps arrive — paper Alg. 4/5). Requests arriving
//! for a line with an active entry are queued on it and replayed when the
//! entry retires; same-line fills are merged into one downstream request.

use crate::mem::fxhash::FxHashMap;
use crate::sim::msg::MemReq;

/// Why the entry was allocated (controllers replay differently).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MshrKind {
    /// Read fill outstanding.
    Fill,
    /// Write forwarded downstream; block locked until timestamps return.
    WriteLock,
}

/// One in-flight line.
#[derive(Debug)]
pub struct MshrEntry {
    pub kind: MshrKind,
    /// The request that allocated the entry.
    pub primary: MemReq,
    /// Requests that arrived while the entry was active, in order.
    pub waiters: Vec<MemReq>,
}

/// The MSHR file for one cache controller. Entries are keyed by line
/// address through the Fx hasher (`mem::fxhash`) — this map sits on the
/// per-request hot path of every cache level.
#[derive(Debug, Default)]
pub struct Mshr {
    entries: FxHashMap<u64, MshrEntry>,
    capacity: usize,
    /// Peak simultaneous entries (metrics).
    pub peak: usize,
    /// Total merges onto existing entries (metrics).
    pub merges: u64,
}

impl Mshr {
    pub fn new(capacity: usize) -> Self {
        Mshr { entries: FxHashMap::default(), capacity, peak: 0, merges: 0 }
    }

    /// Whether a new entry can be allocated.
    pub fn has_free(&self) -> bool {
        self.entries.len() < self.capacity
    }

    /// Active entry for `line_addr`, if any.
    pub fn get(&self, line_addr: u64) -> Option<&MshrEntry> {
        self.entries.get(&line_addr)
    }

    pub fn get_mut(&mut self, line_addr: u64) -> Option<&mut MshrEntry> {
        self.entries.get_mut(&line_addr)
    }

    /// Allocate an entry; panics if one exists (controller bug) or the file
    /// is full (controllers must check `has_free` and stall otherwise).
    pub fn allocate(&mut self, line_addr: u64, kind: MshrKind, primary: MemReq) {
        assert!(self.has_free(), "MSHR overflow at {line_addr:#x}");
        let prev = self.entries.insert(
            line_addr,
            MshrEntry { kind, primary, waiters: Vec::new() },
        );
        assert!(prev.is_none(), "duplicate MSHR entry for {line_addr:#x}");
        self.peak = self.peak.max(self.entries.len());
    }

    /// Queue `req` behind the active entry for `line_addr`.
    pub fn merge(&mut self, line_addr: u64, req: MemReq) {
        self.merges += 1;
        self.entries
            .get_mut(&line_addr)
            .unwrap_or_else(|| panic!("merge without entry for {line_addr:#x}"))
            .waiters
            .push(req);
    }

    /// Retire the entry, returning it for replay.
    pub fn retire(&mut self, line_addr: u64) -> MshrEntry {
        self.entries
            .remove(&line_addr)
            .unwrap_or_else(|| panic!("retire without entry for {line_addr:#x}"))
    }

    /// Number of active entries.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Serialize the mutable state (docs/SNAPSHOT.md). Entries are
    /// written sorted by line address — hash-map iteration order is not
    /// deterministic, and snapshot bytes must be. Capacity comes from
    /// the config and is not written.
    pub fn save_state(&self, out: &mut Vec<u8>) {
        use crate::snapshot::format as f;
        f::put(out, self.peak as u64);
        f::put(out, self.merges);
        f::put(out, self.entries.len() as u64);
        let mut addrs: Vec<u64> = self.entries.keys().copied().collect();
        addrs.sort_unstable();
        for addr in addrs {
            let e = &self.entries[&addr];
            f::put(out, addr);
            out.push(match e.kind {
                MshrKind::Fill => 0,
                MshrKind::WriteLock => 1,
            });
            f::put_req(out, &e.primary);
            f::put(out, e.waiters.len() as u64);
            for w in &e.waiters {
                f::put_req(out, w);
            }
        }
    }

    /// Restore the state written by [`Mshr::save_state`].
    pub fn load_state(&mut self, cur: &mut crate::snapshot::format::Cur) -> Result<(), String> {
        use crate::snapshot::format as f;
        self.peak = cur.u64("mshr peak")? as usize;
        self.merges = cur.u64("mshr merges")?;
        let n = cur.u64("mshr entry count")? as usize;
        if n > self.capacity {
            return Err(format!(
                "snapshot MSHR holds {n} entries, this configuration allows {} — the \
                 configurations differ",
                self.capacity
            ));
        }
        self.entries.clear();
        for _ in 0..n {
            let addr = cur.u64("mshr line addr")?;
            let kind = match cur.byte("mshr entry kind")? {
                0 => MshrKind::Fill,
                1 => MshrKind::WriteLock,
                k => return Err(format!("mshr entry kind must be 0 or 1, got {k}")),
            };
            let primary = f::read_req(cur, "mshr primary")?;
            let n_waiters = cur.u64("mshr waiter count")? as usize;
            if n_waiters > cur.b.len() {
                return Err(format!("mshr waiter count {n_waiters} exceeds the input size"));
            }
            let mut waiters = Vec::with_capacity(n_waiters);
            for _ in 0..n_waiters {
                waiters.push(f::read_req(cur, "mshr waiter")?);
            }
            if self.entries.insert(addr, MshrEntry { kind, primary, waiters }).is_some() {
                return Err(format!("snapshot MSHR repeats line address {addr:#x}"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::engine::CompId;
    use crate::sim::msg::ReqKind;

    fn req(id: u64, addr: u64) -> MemReq {
        MemReq {
            id,
            kind: ReqKind::Read,
            addr,
            size: 4,
            src: CompId(0),
            dst: CompId(1),
            data: crate::mem::LineBuf::empty(),
            warpts: None,
            tenant: 0,
        }
    }

    #[test]
    fn allocate_merge_retire_preserves_order() {
        let mut m = Mshr::new(4);
        m.allocate(0x40, MshrKind::Fill, req(1, 0x40));
        m.merge(0x40, req(2, 0x44));
        m.merge(0x40, req(3, 0x48));
        assert_eq!(m.len(), 1);
        assert_eq!(m.merges, 2);
        let e = m.retire(0x40);
        assert_eq!(e.primary.id, 1);
        let ids: Vec<u64> = e.waiters.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![2, 3]);
        assert!(m.is_empty());
    }

    #[test]
    fn capacity_gates_allocation() {
        let mut m = Mshr::new(2);
        m.allocate(0x00, MshrKind::Fill, req(1, 0));
        assert!(m.has_free());
        m.allocate(0x40, MshrKind::WriteLock, req(2, 0x40));
        assert!(!m.has_free());
        m.retire(0x00);
        assert!(m.has_free());
        assert_eq!(m.peak, 2);
    }

    #[test]
    #[should_panic(expected = "duplicate MSHR entry")]
    fn duplicate_allocation_panics() {
        let mut m = Mshr::new(4);
        m.allocate(0x40, MshrKind::Fill, req(1, 0x40));
        m.allocate(0x40, MshrKind::Fill, req(2, 0x40));
    }

    #[test]
    fn kinds_are_tracked() {
        let mut m = Mshr::new(4);
        m.allocate(0x80, MshrKind::WriteLock, req(9, 0x80));
        assert_eq!(m.get(0x80).unwrap().kind, MshrKind::WriteLock);
    }
}
