//! Functional backing store for the whole MGPU system.
//!
//! One `GlobalMemory` instance backs every memory controller: the physical
//! address space is singular regardless of topology (under RDMA it is
//! *partitioned*, not duplicated). Storage is sparse at line granularity —
//! workloads touch tens of MB out of a multi-GB space.
//!
//! Perf notes (§Perf log): lines are stored as inline `[u8; 64]` values
//! keyed by a dependency-free FxHash-style `u64` hasher (`mem::fxhash`) —
//! the SipHash default burned ~5% of runtime on line lookups — and
//! [`read_line`](GlobalMemory::read_line) copies out by value into an
//! inline [`LineBuf`] instead of cloning a heap box per access.
//!
//! The store is shared between MC components and the coordinator via
//! `Arc<SharedCell>` ([`SharedMemory`]). Under the sharded engine
//! (`sim::shard`) memory controllers on different shards may access the
//! store concurrently; accesses are short (one line copy) and — in the
//! RDMA topologies, the only ones that place MCs outside the hub shard —
//! touch disjoint per-GPU address partitions, so a plain mutex is both
//! correct and cheap, and the access counters stay deterministic (only
//! commutative increments race).

use std::sync::{Arc, Mutex, MutexGuard};

use crate::mem::fxhash::FxHashMap;
use crate::mem::linebuf::LineBuf;
use crate::mem::LINE;

/// Sparse line-granular memory.
#[derive(Debug, Default)]
pub struct GlobalMemory {
    lines: FxHashMap<u64, [u8; LINE as usize]>,
    /// Functional accesses (metrics / debugging).
    pub reads: u64,
    pub writes: u64,
}

/// Lock wrapper keeping the historical `RefCell`-style `borrow_mut()`
/// call sites intact while making the store shareable across the
/// engine's worker threads.
#[derive(Debug, Default)]
pub struct SharedCell {
    inner: Mutex<GlobalMemory>,
}

impl SharedCell {
    /// Exclusive access to the store (a mutex lock; the name mirrors the
    /// pre-sharding `RefCell` API). Poisoning is ignored: a panicking
    /// simulation cell is reported by the engine, and the store's
    /// line-granular state stays consistent (no multi-line invariants).
    pub fn borrow_mut(&self) -> MutexGuard<'_, GlobalMemory> {
        self.inner.lock().unwrap_or_else(|p| p.into_inner())
    }
}

/// Shared handle used by memory controllers and the coordinator.
pub type SharedMemory = Arc<SharedCell>;

impl GlobalMemory {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn new_shared() -> SharedMemory {
        Arc::new(SharedCell { inner: Mutex::new(Self::new()) })
    }

    fn line_base(addr: u64) -> u64 {
        addr & !(LINE - 1)
    }

    /// Copy out the 64-byte line containing `addr` (zeros if untouched).
    /// Returns an inline buffer — no heap traffic.
    pub fn read_line(&mut self, addr: u64) -> LineBuf {
        self.reads += 1;
        let base = Self::line_base(addr);
        match self.lines.get(&base) {
            Some(line) => LineBuf::from_slice(line),
            None => LineBuf::zeroed(LINE as usize),
        }
    }

    /// Write `data` starting at `addr` (may span lines).
    pub fn write_bytes(&mut self, addr: u64, data: &[u8]) {
        self.writes += 1;
        let mut cur = addr;
        let mut remaining = data;
        while !remaining.is_empty() {
            let base = Self::line_base(cur);
            let off = (cur - base) as usize;
            let n = remaining.len().min(LINE as usize - off);
            let line = self.lines.entry(base).or_insert([0u8; LINE as usize]);
            line[off..off + n].copy_from_slice(&remaining[..n]);
            cur += n as u64;
            remaining = &remaining[n..];
        }
    }

    /// Read `n` bytes starting at `addr` (may span lines).
    pub fn read_bytes(&mut self, addr: u64, n: usize) -> Vec<u8> {
        let mut out = Vec::with_capacity(n);
        let mut cur = addr;
        while out.len() < n {
            let base = Self::line_base(cur);
            let off = (cur - base) as usize;
            let take = (n - out.len()).min(LINE as usize - off);
            match self.lines.get(&base) {
                Some(line) => out.extend_from_slice(&line[off..off + take]),
                None => out.extend(std::iter::repeat_n(0u8, take)),
            }
            cur += take as u64;
        }
        self.reads += 1;
        out
    }

    /// Typed helpers for f32 workload data.
    pub fn write_f32(&mut self, addr: u64, v: f32) {
        self.write_bytes(addr, &v.to_le_bytes());
    }

    pub fn read_f32(&mut self, addr: u64) -> f32 {
        let b = self.read_bytes(addr, 4);
        f32::from_le_bytes([b[0], b[1], b[2], b[3]])
    }

    pub fn write_f32_slice(&mut self, addr: u64, vs: &[f32]) {
        let mut bytes = Vec::with_capacity(vs.len() * 4);
        for v in vs {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        self.write_bytes(addr, &bytes);
    }

    pub fn read_f32_vec(&mut self, addr: u64, n: usize) -> Vec<f32> {
        let bytes = self.read_bytes(addr, n * 4);
        bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect()
    }

    /// Number of distinct lines touched.
    pub fn resident_lines(&self) -> usize {
        self.lines.len()
    }

    /// Serialize the full store for a snapshot (docs/SNAPSHOT.md).
    /// Lines are written sorted by address — hash-map iteration order
    /// is not deterministic, and snapshot bytes must be.
    pub fn save_state(&self, out: &mut Vec<u8>) {
        use crate::snapshot::format::put;
        put(out, self.reads);
        put(out, self.writes);
        put(out, self.lines.len() as u64);
        let mut addrs: Vec<u64> = self.lines.keys().copied().collect();
        addrs.sort_unstable();
        for addr in addrs {
            put(out, addr);
            out.extend_from_slice(&self.lines[&addr]);
        }
    }

    /// Restore the state written by [`GlobalMemory::save_state`],
    /// replacing any current contents.
    pub fn load_state(&mut self, cur: &mut crate::snapshot::format::Cur) -> Result<(), String> {
        self.reads = cur.u64("memory reads")?;
        self.writes = cur.u64("memory writes")?;
        let n = cur.u64("memory line count")? as usize;
        if n.saturating_mul(LINE as usize) > cur.b.len() {
            return Err(format!("memory line count {n} exceeds the input size"));
        }
        self.lines.clear();
        for _ in 0..n {
            let addr = cur.u64("memory line address")?;
            if addr % LINE != 0 {
                return Err(format!("memory line address {addr:#x} is not line-aligned"));
            }
            let bytes = cur.bytes(LINE as usize, "memory line bytes")?;
            let mut line = [0u8; LINE as usize];
            line.copy_from_slice(bytes);
            if self.lines.insert(addr, line).is_some() {
                return Err(format!("snapshot memory repeats line address {addr:#x}"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn untouched_memory_reads_zero() {
        let mut m = GlobalMemory::new();
        assert_eq!(m.read_f32(0x1234), 0.0);
        assert!(m.read_line(0x40).iter().all(|&b| b == 0));
        assert_eq!(m.read_line(0x40).len(), LINE as usize);
    }

    #[test]
    fn f32_roundtrip() {
        let mut m = GlobalMemory::new();
        m.write_f32(100, 3.5);
        m.write_f32(104, -1.25);
        assert_eq!(m.read_f32(100), 3.5);
        assert_eq!(m.read_f32(104), -1.25);
    }

    #[test]
    fn cross_line_write_spans_correctly() {
        let mut m = GlobalMemory::new();
        let data: Vec<u8> = (0..100u8).collect();
        m.write_bytes(60, &data); // starts 4 bytes before a line boundary
        assert_eq!(m.read_bytes(60, 100), data);
        assert_eq!(m.resident_lines(), 3); // lines 0, 64, 128
    }

    #[test]
    fn slice_roundtrip() {
        let mut m = GlobalMemory::new();
        let vs: Vec<f32> = (0..1000).map(|i| i as f32 * 0.5).collect();
        m.write_f32_slice(0x1000, &vs);
        assert_eq!(m.read_f32_vec(0x1000, 1000), vs);
    }

    #[test]
    fn partial_line_update_preserves_rest() {
        let mut m = GlobalMemory::new();
        m.write_bytes(0, &[0xAA; 64]);
        m.write_bytes(16, &[0xBB; 4]);
        let line = m.read_line(0);
        assert_eq!(&line[..16], &[0xAA; 16]);
        assert_eq!(&line[16..20], &[0xBB; 4]);
        assert_eq!(&line[20..], &[0xAA; 44]);
    }

    #[test]
    fn read_line_is_line_aligned_copy() {
        let mut m = GlobalMemory::new();
        m.write_bytes(0x80, &[0x42; 64]);
        // Any address within the line reads the same full line.
        assert_eq!(&m.read_line(0x84)[..], &m.read_line(0x80)[..]);
        assert!(m.read_line(0x84).iter().all(|&b| b == 0x42));
    }
}
