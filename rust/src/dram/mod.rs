//! Main memory: functional backing store + HBM memory controllers
//! (DESIGN.md S7), with the per-stack TSU attached (S8).

pub mod memctrl;
pub mod storage;

pub use memctrl::MemCtrl;
pub use storage::{GlobalMemory, SharedCell, SharedMemory};
