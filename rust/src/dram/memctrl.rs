//! HBM memory controller component.
//!
//! One `MemCtrl` per HBM stack. Models the paper's fixed 100-cycle
//! controller latency (§4.1, "calibrated using a real GPU with HBM
//! memory"); per-stack bandwidth is modelled by the network link feeding
//! the controller and the return link. When coherence is on, the stack's
//! TSU is consulted *in parallel* with the access: TSU latency (50cy) <
//! MC latency (100cy), so the timestamps are ready before the data and add
//! zero time — exactly the paper's Fig. 6 claim. The TSU's occupancy and
//! traffic are still fully accounted.

use crate::dram::storage::SharedMemory;
use crate::sim::msg::{MemRsp, TsPair};
use crate::sim::{CompId, Component, Ctx, Cycle, LinkId, Msg, ReqKind};
use crate::tsu::Tsu;

/// Statistics exported to the metrics sink.
#[derive(Clone, Copy, Debug, Default)]
pub struct MemCtrlStats {
    pub reads: u64,
    pub writes: u64,
    pub bytes_in: u64,
    pub bytes_out: u64,
}

/// Memory controller + attached HBM stack + optional TSU.
pub struct MemCtrl {
    name: String,
    mem: SharedMemory,
    /// Return path: (link, next-hop component) toward the network.
    up: (LinkId, CompId),
    /// Fixed access latency in cycles.
    latency: Cycle,
    /// Timestamp storage unit (HALCONE configurations only).
    pub tsu: Option<Tsu>,
    pub stats: MemCtrlStats,
    line: u64,
}

impl MemCtrl {
    pub fn new(
        name: impl Into<String>,
        mem: SharedMemory,
        up: (LinkId, CompId),
        latency: Cycle,
        tsu: Option<Tsu>,
    ) -> Self {
        MemCtrl {
            name: name.into(),
            mem,
            up,
            latency,
            tsu,
            stats: MemCtrlStats::default(),
            line: crate::mem::LINE,
        }
    }

    fn ts_for(&mut self, now: Cycle, kind: ReqKind, line_addr: u64) -> Option<TsPair> {
        self.tsu.as_mut().map(|tsu| match kind {
            ReqKind::Read => tsu.on_read(line_addr, now),
            ReqKind::Write => tsu.on_write(line_addr, now),
        })
    }
}

impl Component for MemCtrl {
    crate::impl_component_any!();
    fn name(&self) -> &str {
        &self.name
    }

    fn handle(&mut self, now: Cycle, msg: Msg, ctx: &mut Ctx) {
        let req = match msg {
            Msg::Req(r) => ctx.reclaim_req(r),
            other => panic!("{}: unexpected {:?}", self.name, other),
        };
        let line_addr = req.addr & !(self.line - 1);
        self.stats.bytes_in += req.wire_bytes();

        // TSU lookup runs in parallel with the DRAM access (free in
        // time); `now` feeds the HLC policy's physical clock component.
        let ts = self.ts_for(now, req.kind, line_addr);

        // Both paths copy the line into an inline buffer — no heap.
        let data = match req.kind {
            ReqKind::Read => {
                self.stats.reads += 1;
                self.mem.borrow_mut().read_line(line_addr)
            }
            ReqKind::Write => {
                self.stats.writes += 1;
                let mut mem = self.mem.borrow_mut();
                mem.write_bytes(req.addr, &req.data);
                // Return the merged line so write-allocate levels can fill.
                mem.read_line(line_addr)
            }
        };

        let rsp = MemRsp {
            id: req.id,
            kind: req.kind,
            addr: req.addr,
            dst: req.src,
            data,
            ts,
        };
        self.stats.bytes_out += rsp.wire_bytes();
        let (link, next) = self.up;
        let bytes = rsp.wire_bytes();
        let msg = ctx.rsp_msg(rsp);
        ctx.send_delayed(self.latency, link, next, bytes, msg);
    }

    fn save_state(&self, out: &mut Vec<u8>) -> Result<(), String> {
        use crate::snapshot::format::{put, put_bool};
        put(out, self.stats.reads);
        put(out, self.stats.writes);
        put(out, self.stats.bytes_in);
        put(out, self.stats.bytes_out);
        put_bool(out, self.tsu.is_some());
        if let Some(tsu) = &self.tsu {
            tsu.save_state(out);
        }
        Ok(())
    }

    fn load_state(&mut self, cur: &mut crate::snapshot::format::Cur) -> Result<(), String> {
        self.stats.reads = cur.u64("mc reads")?;
        self.stats.writes = cur.u64("mc writes")?;
        self.stats.bytes_in = cur.u64("mc bytes_in")?;
        self.stats.bytes_out = cur.u64("mc bytes_out")?;
        let has_tsu = cur.bool("mc tsu flag")?;
        match (&mut self.tsu, has_tsu) {
            (Some(tsu), true) => tsu.load_state(cur),
            (None, false) => Ok(()),
            (mine, _) => Err(format!(
                "snapshot memory controller {} a TSU, this configuration {} one — the \
                 coherence settings differ",
                if has_tsu { "has" } else { "lacks" },
                if mine.is_some() { "builds" } else { "omits" },
            )),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dram::storage::GlobalMemory;
    use crate::mem::LineBuf;
    use crate::sim::msg::MemReq;
    use crate::sim::{Engine, Link};
    use crate::tsu::Leases;

    struct Collector {
        name: String,
        rsps: Vec<(Cycle, MemRsp)>,
    }
    impl Component for Collector {
    crate::impl_component_any!();
        fn name(&self) -> &str {
            &self.name
        }
        fn handle(&mut self, now: Cycle, msg: Msg, _ctx: &mut Ctx) {
            if let Msg::Rsp(r) = msg {
                self.rsps.push((now, *r));
            }
        }
    }

    fn setup(tsu: bool) -> (Engine, SharedMemory, CompId, CompId) {
        let mut e = Engine::new();
        let mem = GlobalMemory::new_shared();
        let up = e.add_link(Link::new("mc->l2", 10, 341));
        let mc_id = CompId(0);
        let l2_id = CompId(1);
        let tsu = tsu.then(|| Tsu::new(4096, Leases::default()));
        e.add(Box::new(MemCtrl::new("mm0", mem.clone(), (up, l2_id), 100, tsu)));
        e.add(Box::new(Collector { name: "l2".into(), rsps: vec![] }));
        (e, mem, mc_id, l2_id)
    }

    fn read_req(id: u64, addr: u64, src: CompId, dst: CompId) -> Msg {
        Msg::Req(Box::new(MemReq {
            id,
            kind: ReqKind::Read,
            addr,
            size: 64,
            src,
            dst,
            data: LineBuf::empty(),
            warpts: None,
            tenant: 0,
        }))
    }

    #[test]
    fn read_returns_line_after_latency() {
        let (mut e, mem, mc, l2) = setup(false);
        mem.borrow_mut().write_f32(0x40, 7.5);
        e.post(0, mc, read_req(1, 0x40, l2, mc));
        e.run_to_completion();
        let c = e.component(l2);
        let _ = c;
        // Verify timing through the link: response entered at t=100,
        // 72 bytes @341B/cy = 1 cycle, +10 latency => t=111.
        assert_eq!(e.now(), 111);
    }

    #[test]
    fn write_merges_and_returns_full_line() {
        let (mut e, mem, mc, l2) = setup(false);
        mem.borrow_mut().write_bytes(0x80, &[0xAA; 64]);
        e.post(
            0,
            mc,
            Msg::Req(Box::new(MemReq {
                id: 2,
                kind: ReqKind::Write,
                addr: 0x84,
                size: 4,
                src: l2,
                dst: mc,
                data: LineBuf::from_slice(&[1, 2, 3, 4]),
                warpts: None,
                tenant: 0,
            })),
        );
        e.run_to_completion();
        let mut m = mem.borrow_mut();
        assert_eq!(m.read_bytes(0x84, 4), vec![1, 2, 3, 4]);
        assert_eq!(m.read_bytes(0x80, 4), vec![0xAA; 4]); // rest preserved
    }

    #[test]
    fn tsu_attaches_timestamps_without_extra_latency() {
        let (mut e, _mem, mc, l2) = setup(true);
        e.post(0, mc, read_req(3, 0x40, l2, mc));
        let end_with_tsu = {
            e.run_to_completion();
            e.now()
        };
        // Same access without TSU: the response is 4 bytes smaller but the
        // cycle count must be identical (TSU off the critical path).
        let (mut e2, _m2, mc2, l2b) = setup(false);
        e2.post(0, mc2, read_req(3, 0x40, l2b, mc2));
        e2.run_to_completion();
        assert_eq!(end_with_tsu, e2.now());
    }
}
